"""Bank-axis sharding — tree-range partitioned FilterBank over the mesh.

The paper's many-tree regime ("hundreds of times faster ... when the number
of trees is large") only scales past one device if the *tree axis* shards:
a replicated ``(T, NB, S)`` bank caps T at a single device's memory and
adding devices buys nothing.  Here the bank partitions into contiguous
tree ranges over the ``model`` mesh axis (``FilterBank.shard`` /
``plan_partition`` pick ranges balanced by per-tree row counts) and queries
travel to their data instead of the data being everywhere:

1. each device holds its slice of the query batch; a query's owning shard
   comes from the replicated ``tree_shard`` routing table;
2. queries bucket by destination and exchange once with
   ``jax.lax.all_to_all`` inside ``shard_map`` (no full-bank broadcast);
3. every shard probes only its own ``(Tpad, NB, S)`` block — the same
   two-candidate-bucket ``match_rows`` semantics as ``lookup_batch_bank``,
   with per-shard NB so shard-local expansions can diverge bucket counts;
4. results (and nothing else) route back through the inverse all-to-all —
   there is no max-reduce over T x NB x S replicas anywhere.

Temperature bumps land in the owning shard's block during the probe, so
the paper's feedback loop stays shard-local too; the host harvests with
``ShardedBank.absorb_temperature`` (per-shard baselines, never
double-counted).

The legacy single-filter helpers (``shard_filter_tables`` +
``sharded_lookup``) are thin wrappers over the same router: a bucket-striped
filter is just a degenerate bank whose "trees" are the D bucket stripes,
with each query fanned to its two candidate stripes and the pair merged
with i1 priority.
"""
from __future__ import annotations

import dataclasses
import functools
from typing import Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

from ..compat import shard_map as _shard_map
from . import hashing
from .bank import FilterBank, ShardedBank
from .lookup import LookupResult, match_rows, sort_buckets_bank
from .tree import EntityForest
from .trag import CFTDeviceState, DeviceRetrieval, gather_context

NULL = -1


# ---------------------------------------------------------------- router

def _exchange(buf: jax.Array, axis: str) -> jax.Array:
    """One all-to-all hop: local ``(D, C, ...)`` buffer -> local
    ``(D, C, ...)`` buffer whose row s holds what source shard s sent us.
    Involutive — the same call routes results back."""
    return jax.lax.all_to_all(buf, axis, 0, 0, tiled=True)


def _bucket_queries(dest: jax.Array, num_shards: int,
                    payloads: Tuple[Tuple[jax.Array, object], ...]
                    ) -> Tuple[jax.Array, Tuple[jax.Array, ...]]:
    """Pack per-query payloads into fixed ``(D, C)`` destination buckets.

    ``dest``: (Bl,) destination shard per local query.  Capacity C equals
    Bl (the degenerate case routes every local query to one shard), so no
    bucket can overflow and shapes stay static.  Returns each query's slot
    ``rank`` within its bucket — the return address for ``_route_back`` —
    plus one ``(D, C)`` buffer per (payload, fill) pair.
    """
    bl = dest.shape[0]
    order = jnp.argsort(dest)                       # stable
    counts = jnp.bincount(dest, length=num_shards)
    starts = jnp.concatenate([jnp.zeros((1,), counts.dtype),
                              jnp.cumsum(counts)[:-1]])
    within = (jnp.arange(bl) - starts[dest[order]]).astype(jnp.int32)
    rank = jnp.zeros((bl,), jnp.int32).at[order].set(within)
    bufs = tuple(
        jnp.full((num_shards, bl), fill, x.dtype).at[dest, rank].set(x)
        for x, fill in payloads)
    return rank, bufs


def _route_back(x: jax.Array, dest: jax.Array, rank: jax.Array,
                axis: str, num_shards: int) -> jax.Array:
    """Send per-slot probe results home and unscatter to query order."""
    recv = _exchange(x.reshape(num_shards, -1), axis)
    return recv[dest, rank]


# ------------------------------------------------------- sharded bank state

@jax.tree_util.register_pytree_node_class
@dataclasses.dataclass
class ShardedBankState:
    """Device-side bank-axis sharded retrieval state.

    Filter tables are *packed*: shard d's trees live in block rows
    ``[d*Tpad, d*Tpad + Td)`` of a ``(D*Tpad, NBmax, S)`` tensor placed
    ``P(axis, None, None)`` over the mesh, so each device holds exactly one
    shard's block (1/D of the replicated table bytes, padding aside).
    Routing tables, the merged CSR location arena and the forest hierarchy
    arrays are replicated — they are O(T) / O(rows), not O(T*NB*S).

    ``shard_nb`` carries each shard's true bucket count: after a
    shard-local expansion the packed layout pads to the max NB, and the
    probe derives candidate buckets from the owning shard's own NB.
    ``mesh``/``axis``/``uniform_nb`` are static (pytree aux), so the state
    passes through ``jax.jit`` like any other pytree.
    """
    fingerprints: jax.Array   # (D*Tpad, NBmax, S) uint32, P(axis, None, None)
    temperature: jax.Array    # (D*Tpad, NBmax, S) int32
    heads: jax.Array          # (D*Tpad, NBmax, S) int32 — merged CSR row ids
    tree_shard: jax.Array     # (T,) int32 — owning shard, replicated
    tree_local: jax.Array     # (T,) int32 — index within the owner's block
    shard_nb: jax.Array       # (D,) int32 — per-shard true bucket count
    csr_offsets: jax.Array    # (R + 1,) int32 — merged arena, replicated
    csr_nodes: jax.Array      # (L,) int32
    parent: jax.Array         # (N,) int32 — forest arrays, replicated
    entity_id: jax.Array      # (N,) int32
    child_offsets: jax.Array  # (N + 1,) int32
    child_index: jax.Array    # (C,) int32
    mesh: Mesh                # static
    axis: str                 # static
    uniform_nb: Optional[int]  # static; set iff every shard shares one NB

    _LEAVES = ("fingerprints", "temperature", "heads", "tree_shard",
               "tree_local", "shard_nb", "csr_offsets", "csr_nodes",
               "parent", "entity_id", "child_offsets", "child_index")

    def tree_flatten(self):
        return (tuple(getattr(self, f) for f in self._LEAVES),
                (self.mesh, self.axis, self.uniform_nb))

    @classmethod
    def tree_unflatten(cls, aux, children):
        return cls(*children, *aux)

    # --------------------------------------------------------------- sizes
    @property
    def num_shards(self) -> int:
        return int(self.mesh.shape[self.axis])

    @property
    def trees_per_shard(self) -> int:
        return int(self.fingerprints.shape[0]) // self.num_shards

    @property
    def num_trees(self) -> int:
        return int(self.tree_shard.shape[0])

    @property
    def slots(self) -> int:
        return int(self.fingerprints.shape[-1])

    # ----------------------------------------------------------- threading
    def with_temperature(self, temperature: jax.Array) -> "ShardedBankState":
        """Thread an updated packed temperature forward (same contract as
        ``CFTDeviceState.with_temperature``)."""
        return dataclasses.replace(self, temperature=temperature)

    def sort_idle(self) -> "ShardedBankState":
        """Device-only idle-time bucket sort over every shard's block at
        once (pure per-bucket slot reorder — sharding is preserved).  As
        with ``CFTDeviceState.sort_idle``: only for states with no host
        bank mirror; a host ``ShardedMaintenanceEngine`` sorts + restages
        instead so layouts never diverge."""
        f, t, h = sort_buckets_bank(self.fingerprints, self.temperature,
                                    self.heads)
        return dataclasses.replace(self, fingerprints=f, temperature=t,
                                   heads=h)


def stage_sharded_bank(sbank: ShardedBank, forest: EntityForest,
                       mesh: Mesh, axis: str = "model") -> ShardedBankState:
    """Place a host :class:`ShardedBank` on the mesh as a
    :class:`ShardedBankState` (packed blocks sharded over ``axis``,
    routing/CSR/forest replicated)."""
    d = int(mesh.shape[axis])
    if d != sbank.num_shards:
        raise ValueError(f"bank has {sbank.num_shards} shards but mesh "
                         f"axis '{axis}' has {d} devices")
    fps, temp, heads = sbank.packed_tables()
    csr_off, csr_nodes = sbank.merged_csr()
    nbs = np.asarray([b.num_buckets for b in sbank.banks], np.int32)
    blk = NamedSharding(mesh, P(axis, None, None))
    rep = NamedSharding(mesh, P())
    put_b = lambda a: jax.device_put(jnp.asarray(a), blk)     # noqa: E731
    put_r = lambda a: jax.device_put(jnp.asarray(a), rep)     # noqa: E731
    fa = CFTDeviceState._forest_arrays(forest)
    return ShardedBankState(
        fingerprints=put_b(fps), temperature=put_b(temp),
        heads=put_b(heads),
        tree_shard=put_r(sbank.tree_shard_map()),
        tree_local=put_r(sbank.tree_local_map()),
        shard_nb=put_r(nbs),
        csr_offsets=put_r(csr_off),
        csr_nodes=put_r(csr_nodes if csr_nodes.size
                        else np.zeros(1, np.int32)),
        parent=put_r(fa["parent"]), entity_id=put_r(fa["entity_id"]),
        child_offsets=put_r(fa["child_offsets"]),
        child_index=put_r(fa["child_index"]),
        mesh=mesh, axis=axis,
        uniform_nb=int(nbs[0]) if np.all(nbs == nbs[0]) else None)


def shard_bank(bank: FilterBank, forest: EntityForest, mesh: Mesh,
               axis: str = "model",
               tree_starts: Optional[np.ndarray] = None
               ) -> Tuple[ShardedBank, ShardedBankState]:
    """Partition + stage in one step; returns (host sbank, device state)."""
    sbank = bank.shard(num_shards=int(mesh.shape[axis]),
                       tree_starts=tree_starts)
    return sbank, stage_sharded_bank(sbank, forest, mesh, axis)


# ------------------------------------------------------- bank-axis lookup

def _bank_local_fn(axis: str, num_shards: int, num_trees: int, slots: int,
                   bump: bool, lookup_fn, uniform_nb: Optional[int]):
    """Build the shard-local body: route -> probe own block -> route back."""

    def local(fps_b, temp_b, heads_b, shard_nb, tree_shard, tree_local,
              tid, h):
        # ---- destination + local coordinates (replicated routing tables)
        tq = jnp.clip(tid, 0, num_trees - 1)
        valid = (tid >= 0) & (tid < num_trees)
        dest = jnp.where(valid, tree_shard[tq], 0).astype(jnp.int32)
        lt = jnp.where(valid, tree_local[tq], 0).astype(jnp.int32)
        rank, (bh, bt, bv) = _bucket_queries(
            dest, num_shards, ((h.astype(jnp.uint32), jnp.uint32(0)),
                               (lt, jnp.int32(0)), (valid, False)))
        # ---- one exchange: every query lands on its owning shard
        qh = _exchange(bh, axis).reshape(-1)
        qt = _exchange(bt, axis).reshape(-1)
        qv = _exchange(bv, axis).reshape(-1)
        # ---- shard-local probe of the owned (Tpad, NBmax, S) block
        if lookup_fn is not None and uniform_nb is not None:
            res = lookup_fn(fps_b, heads_b, qt, qh)
        else:
            nb = shard_nb[jax.lax.axis_index(axis)]
            fp = hashing.fingerprint(qh, jnp)
            i1 = hashing.bucket_i1(qh, nb, jnp)
            i2 = hashing.alt_bucket(i1, fp, nb, jnp)
            res = match_rows(fp, i1, i2, fps_b[qt, i1], fps_b[qt, i2],
                             heads_b[qt, i1], heads_b[qt, i2], slots)
        hit = res.hit & qv
        head = jnp.where(hit, res.head, jnp.int32(NULL))
        if bump:   # owner-local: each tree's temperature has exactly 1 home
            temp_b = temp_b.at[qt, res.bucket, res.slot].add(
                hit.astype(temp_b.dtype))
        # ---- inverse exchange: results home to their source shard
        back = functools.partial(_route_back, dest=dest, rank=rank,
                                 axis=axis, num_shards=num_shards)
        return LookupResult(hit=back(hit), head=back(head),
                            bucket=back(res.bucket),
                            slot=back(res.slot)), temp_b

    return local


def _lookup_core(state: ShardedBankState, tree_ids: jax.Array,
                 h: jax.Array, bump: bool, lookup_fn
                 ) -> Tuple[LookupResult, jax.Array]:
    mesh, axis = state.mesh, state.axis
    d = state.num_shards
    b = h.shape[0]
    pad = (-b) % d
    tid = jnp.pad(tree_ids.astype(jnp.int32), (0, pad),
                  constant_values=NULL)            # pad queries always miss
    hp = jnp.pad(h.astype(jnp.uint32), (0, pad))
    local = _bank_local_fn(axis, d, state.num_trees, state.slots, bump,
                           lookup_fn, state.uniform_nb)
    spec_b = P(axis, None, None)
    fn = _shard_map(
        local, mesh=mesh,
        in_specs=(spec_b, spec_b, spec_b, P(), P(), P(), P(axis), P(axis)),
        out_specs=(LookupResult(hit=P(axis), head=P(axis), bucket=P(axis),
                                slot=P(axis)), spec_b),
        # pallas_call has no replication rule; rep-check only costs us the
        # kernel probe path, so switch it off just there
        check_rep=lookup_fn is None)
    res, temp = fn(state.fingerprints, state.temperature, state.heads,
                   state.shard_nb, state.tree_shard, state.tree_local,
                   tid, hp)
    return LookupResult(hit=res.hit[:b], head=res.head[:b],
                        bucket=res.bucket[:b], slot=res.slot[:b]), temp


@functools.partial(jax.jit, static_argnames=("lookup_fn",))
def sharded_lookup_bank(state: ShardedBankState, tree_ids: jax.Array,
                        h: jax.Array, lookup_fn=None) -> LookupResult:
    """All-to-all routed bank lookup; bit-identical to
    ``lookup_batch_bank`` over the merged replicated tables.

    ``lookup_fn(fps, heads, tree_ids, h)`` swaps in a different shard-local
    probe (e.g. the tiled Pallas bank kernel
    ``repro.kernels.cuckoo_lookup.cuckoo_lookup_bank_auto``); it is used
    only while every shard shares one NB — after per-shard expansions
    diverge bucket counts, the probe falls back to the pure-jnp path, which
    reads each shard's NB from the routing tables.  Pure: temperature is
    not bumped (use :func:`sharded_retrieve_device` for serving).
    """
    res, _ = _lookup_core(state, tree_ids, h, bump=False,
                          lookup_fn=lookup_fn)
    return res


@functools.partial(jax.jit,
                   static_argnames=("max_locs", "n", "lookup_fn"))
def sharded_retrieve_device(state: ShardedBankState,
                            query_hashes: jax.Array,
                            query_trees: Optional[jax.Array] = None,
                            max_locs: int = 4, n: int = 3,
                            lookup_fn=None) -> DeviceRetrieval:
    """Bank-axis sharded analogue of ``repro.core.retrieve_device``.

    The lookup routes through the all-to-all; temperature bumps land in
    the owning shard's packed block during the probe (so the returned
    ``temperature`` keeps the sharded layout — thread it forward with
    ``state.with_temperature``); the CSR location gather and hierarchy
    windows run on the replicated arrays exactly as the replicated path.
    """
    if query_trees is None:
        query_trees = jnp.zeros(query_hashes.shape, jnp.int32)
    res, temp = _lookup_core(state, query_trees, query_hashes, bump=True,
                             lookup_fn=lookup_fn)
    return gather_context(state, res, temp, max_locs=max_locs, n=n)


# ------------------------------------------- legacy single-filter wrappers

def _filter_local_fn(axis: str, num_shards: int, nb_global: int,
                     nb_local: int, slots: int):
    """Shard-local body for the bucket-striped single filter: each query
    fans out to its two candidate stripes through the shared router, each
    stripe scans one bucket row, and the pair merges with i1 priority."""

    def local(fps_s, heads_s, h_l):
        bl = h_l.shape[0]
        fp, i1, i2 = hashing.candidate_buckets(h_l.astype(jnp.uint32),
                                               nb_global, jnp)
        # 2 routed probes per query: [all i1 probes ; all i2 probes]
        cand = jnp.concatenate([i1, i2]).astype(jnp.int32)
        dest = cand // nb_local                    # stripe == owning shard
        lb = cand % nb_local
        fp2 = jnp.tile(fp, 2)
        rank, (bb, bf) = _bucket_queries(
            dest, num_shards, ((lb, jnp.int32(0)), (fp2, jnp.uint32(0))))
        qb = _exchange(bb, axis).reshape(-1)
        qf = _exchange(bf, axis).reshape(-1)
        rows = fps_s[qb]                           # (D*C, S)
        m = rows == qf[:, None]
        hit = jnp.any(m, axis=1)
        slot = jnp.argmax(m, axis=1).astype(jnp.int32)
        head = jnp.take_along_axis(heads_s[qb], slot[:, None],
                                   axis=1)[:, 0]
        back = functools.partial(_route_back, dest=dest, rank=rank,
                                 axis=axis, num_shards=num_shards)
        hit, head, slot = back(hit), back(head), back(slot)
        h1, h2 = hit[:bl], hit[bl:]
        # i1 priority — identical tie-breaking to match_rows' 2S concat
        return LookupResult(
            hit=h1 | h2,
            head=jnp.where(h1, head[:bl],
                           jnp.where(h2, head[bl:], jnp.int32(NULL))),
            bucket=jnp.where(h1 | ~h2, i1, i2).astype(jnp.int32),
            slot=jnp.where(h1, slot[:bl],
                           jnp.where(h2, slot[bl:], jnp.int32(0))))

    return local


def sharded_lookup(mesh: Mesh, axis: str, fingerprints: jax.Array,
                   heads: jax.Array, h: jax.Array) -> LookupResult:
    """Single-filter lookup with tables bucket-sharded over ``axis``.

    Thin wrapper over the bank-axis router: the D bucket stripes act as a
    degenerate D-tree bank (one "tree" per shard), each query routes to its
    two candidate stripes, and no replica combine exists — the old
    replicated-query pmax path is gone.  Bit-identical to
    ``lookup_batch``.
    """
    nb_global, slots = fingerprints.shape
    d = int(mesh.shape[axis])
    if nb_global % d:
        raise ValueError(f"bucket count {nb_global} not divisible by "
                         f"mesh axis size {d}")
    b = h.shape[0]
    pad = (-b) % d
    hp = jnp.pad(h.astype(jnp.uint32), (0, pad))
    local = _filter_local_fn(axis, d, nb_global, nb_global // d, slots)
    fn = _shard_map(
        local, mesh=mesh,
        in_specs=(P(axis, None), P(axis, None), P(axis)),
        out_specs=LookupResult(hit=P(axis), head=P(axis), bucket=P(axis),
                               slot=P(axis)))
    res = fn(fingerprints, heads, hp)
    return LookupResult(hit=res.hit[:b], head=res.head[:b],
                        bucket=res.bucket[:b], slot=res.slot[:b])


def shard_filter_tables(mesh: Mesh, axis: str, *tables: jax.Array
                        ) -> Tuple[jax.Array, ...]:
    """Place filter tables bucket-sharded on the mesh."""
    sharding = NamedSharding(mesh, P(axis, None))
    return tuple(jax.device_put(t, sharding) for t in tables)
