"""Distributed cuckoo-filter lookup — buckets sharded across the mesh.

At pod scale the entity forest can exceed a single host's memory; the filter
(and the CSR location arena) shard over the ``model`` mesh axis.  Queries are
replicated (they are tiny — B hashes), every shard probes only the buckets it
owns, and partial results combine with a max-reduce (misses are -1, hits are
unique because an entity lives in exactly one or two buckets, both possibly
on different shards — each shard reports only local hits).

This is shard_map-native: no pointer chasing crosses devices, one psum-style
combine per lookup round.
"""
from __future__ import annotations

import functools
from typing import Tuple

import jax
import jax.numpy as jnp
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

from ..compat import shard_map as _shard_map
from . import hashing
from .lookup import LookupResult


def _local_probe(fps_shard: jax.Array, heads_shard: jax.Array,
                 h: jax.Array, axis_name: str,
                 nb_global: int) -> LookupResult:
    """Probe only the locally-owned bucket range; miss -> -1 everywhere."""
    nb_local, s = fps_shard.shape
    shard = jax.lax.axis_index(axis_name)
    lo = shard * nb_local

    fp, i1, i2 = hashing.candidate_buckets(h.astype(jnp.uint32), nb_global, jnp)
    out_hit = jnp.zeros(h.shape, dtype=jnp.bool_)
    out_head = jnp.full(h.shape, -1, dtype=jnp.int32)
    out_bucket = jnp.full(h.shape, -1, dtype=jnp.int32)
    out_slot = jnp.full(h.shape, -1, dtype=jnp.int32)

    for cand in (i1, i2):
        local = cand.astype(jnp.int32) - lo
        owned = (local >= 0) & (local < nb_local)
        safe = jnp.clip(local, 0, nb_local - 1)
        rows = fps_shard[safe]                       # (B, S)
        match = (rows == fp[:, None]) & owned[:, None]
        hit = jnp.any(match, axis=1)
        slot = jnp.argmax(match, axis=1).astype(jnp.int32)
        head = jnp.take_along_axis(heads_shard[safe], slot[:, None], axis=1)[:, 0]
        take = hit & ~out_hit                        # i1 priority over i2
        out_hit = out_hit | hit
        out_head = jnp.where(take, head, out_head)
        out_bucket = jnp.where(take, cand.astype(jnp.int32), out_bucket)
        out_slot = jnp.where(take, slot, out_slot)

    # combine across shards: hits are disjoint per bucket ownership
    combine = functools.partial(jax.lax.pmax, axis_name=axis_name)
    return LookupResult(
        hit=combine(out_hit.astype(jnp.int32)).astype(jnp.bool_),
        head=combine(out_head), bucket=combine(out_bucket),
        slot=combine(out_slot))


def sharded_lookup(mesh: Mesh, axis: str, fingerprints: jax.Array,
                   heads: jax.Array, h: jax.Array) -> LookupResult:
    """Top-level: tables sharded on bucket dim over ``axis``; h replicated."""
    fn = _shard_map(
        functools.partial(_local_probe, axis_name=axis,
                          nb_global=fingerprints.shape[0]),
        mesh=mesh,
        in_specs=(P(axis, None), P(axis, None), P()),
        out_specs=LookupResult(hit=P(), head=P(), bucket=P(), slot=P()),
    )
    return fn(fingerprints, heads, h)


def shard_filter_tables(mesh: Mesh, axis: str, *tables: jax.Array
                        ) -> Tuple[jax.Array, ...]:
    """Place filter tables bucket-sharded on the mesh."""
    sharding = NamedSharding(mesh, P(axis, None))
    return tuple(jax.device_put(t, sharding) for t in tables)
