"""Bank-axis sharding — tree-range partitioned FilterBank over the mesh.

The paper's many-tree regime ("hundreds of times faster ... when the number
of trees is large") only scales past one device if the *tree axis* shards:
a replicated bank caps T at a single device's memory and adding devices
buys nothing.  Here the bank partitions into contiguous tree ranges over
the ``model`` mesh axis (``FilterBank.shard`` / ``plan_partition`` pick
ranges balanced by per-tree row counts) and queries travel to their data
instead of the data being everywhere:

1. each device holds its slice of the query batch; a query's owning shard
   comes from the replicated ``tree_shard`` routing table;
2. queries bucket by destination and exchange once with
   ``jax.lax.all_to_all`` inside ``shard_map`` (no full-bank broadcast) —
   the receive buffer is worst-case sized by default, or shrunk with a
   ``capacity_factor`` (two-pass: a tiny count exchange first, the factor
   as fast path when the measured counts fit, adaptive growth when not);
3. every shard probes only its own **packed ragged arena block**
   ``(Apad, S)`` — per-tree routing reads each query's arena segment start
   and bucket mask from the replicated per-tree offsets table (the
   generalization of the old per-shard NB table), so tree-local
   expansions diverge per-tree bucket counts freely and the probe is
   bit-identical everywhere;
4. results (and nothing else) route back through the inverse all-to-all —
   there is no max-reduce over replicas anywhere.

Temperature bumps land in the owning shard's arena block during the probe,
so the paper's feedback loop stays shard-local too; the host harvests with
``ShardedBank.absorb_temperature`` (per-shard baselines, never
double-counted).

The legacy single-filter helpers (``shard_filter_tables`` +
``sharded_lookup``) are thin wrappers over the same router: a bucket-striped
filter is just a degenerate bank whose "trees" are the D bucket stripes,
with each query fanned to its two candidate stripes and the pair merged
with i1 priority.
"""
from __future__ import annotations

import dataclasses
import functools
from typing import Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

from ..compat import shard_map as _shard_map
from ..obs import get_registry
from . import hashing
from .bank import FilterBank, ShardedBank, pad_csr
from .lookup import LookupResult, lookup_arena, sort_buckets_arena
from .tree import EntityForest
from .trag import (CFTDeviceState, DeviceRetrieval, finish_context,
                   gather_context)

NULL = -1


# ---------------------------------------------------------------- router

def _exchange(buf: jax.Array, axis: str) -> jax.Array:
    """One all-to-all hop: local ``(D, C, ...)`` buffer -> local
    ``(D, C, ...)`` buffer whose row s holds what source shard s sent us.
    Involutive — the same call routes results back."""
    return jax.lax.all_to_all(buf, axis, 0, 0, tiled=True)


def _bucket_queries(dest: jax.Array, num_shards: int, capacity: int,
                    payloads: Tuple[Tuple[jax.Array, object], ...]
                    ) -> Tuple[jax.Array, Tuple[jax.Array, ...]]:
    """Pack per-query payloads into fixed ``(D, C)`` destination buckets.

    ``dest``: (Bl,) destination shard per local query.  ``capacity`` C
    defaults to Bl upstream (the degenerate case routes every local query
    to one shard, so nothing can overflow); a smaller C comes from the
    two-pass count exchange (``_pick_capacity``), which sizes it from the
    batch's actual per-pair maximum — in-kernel the scatter still drops
    out-of-capacity lanes rather than corrupting memory.  Returns each
    query's slot ``rank`` within its bucket — the return address for
    ``_route_back`` — plus one ``(D, C)`` buffer per (payload, fill) pair.
    """
    bl = dest.shape[0]
    order = jnp.argsort(dest)                       # stable
    counts = jnp.bincount(dest, length=num_shards)
    starts = jnp.concatenate([jnp.zeros((1,), counts.dtype),
                              jnp.cumsum(counts)[:-1]])
    within = (jnp.arange(bl) - starts[dest[order]]).astype(jnp.int32)
    rank = jnp.zeros((bl,), jnp.int32).at[order].set(within)
    bufs = tuple(
        jnp.full((num_shards, capacity), fill, x.dtype)
        .at[dest, rank].set(x, mode="drop")
        for x, fill in payloads)
    return rank, bufs


def _route_back(x: jax.Array, dest: jax.Array, rank: jax.Array,
                axis: str, num_shards: int) -> jax.Array:
    """Send per-slot probe results home and unscatter to query order."""
    recv = _exchange(x.reshape(num_shards, -1), axis)
    return recv[dest, rank]


def _route_back_wide(x: jax.Array, dest: jax.Array, rank: jax.Array,
                     axis: str, num_shards: int) -> jax.Array:
    """Route-back for per-query *row* payloads ``(D*C, W)`` — the fused
    owner probe sends whole CSR location windows home, not scalars."""
    recv = _exchange(x.reshape(num_shards, -1, x.shape[-1]), axis)
    return recv[dest, rank]


# ------------------------------------------------------- sharded bank state

@jax.tree_util.register_pytree_node_class
@dataclasses.dataclass
class ShardedBankState:
    """Device-side bank-axis sharded retrieval state.

    Filter tables are *packed ragged arenas*: shard d's bucket arena lives
    in rows ``[d*Apad, d*Apad + A_d)`` of a ``(D*Apad, S)`` tensor placed
    ``P(axis, None)`` over the mesh, so each device holds exactly one
    shard's arena (true bytes ``sum_t nb_t`` per shard, padding to the
    largest shard aside) — the old dense ``(D*Tpad, NBmax, S)``
    pad-to-max-NB blocks are gone.  Routing tables, the merged CSR
    location arena and the forest hierarchy arrays are replicated — they
    are O(T) / O(rows), not O(arena).

    ``tree_offset``/``tree_nb`` carry each tree's segment start within its
    owning shard's block and its own power-of-two bucket count: the probe
    computes ``tree_offset[t] + (i & (tree_nb[t] - 1))``, so shard- and
    tree-local expansions diverge bucket counts without any uniform-NB
    special case.  ``mesh``/``axis`` are static (pytree aux), so the state
    passes through ``jax.jit`` like any other pytree.
    """
    fingerprints: jax.Array   # (D*Apad, S) uint32, P(axis, None)
    temperature: jax.Array    # (D*Apad, S) int32
    heads: jax.Array          # (D*Apad, S) int32 — merged CSR row ids
    tree_shard: jax.Array     # (T,) int32 — owning shard, replicated
    tree_offset: jax.Array    # (T,) int32 — segment start in owner's block
    tree_nb: jax.Array        # (T,) int32 — per-tree bucket count
    csr_offsets: jax.Array    # (R + 1,) int32 — merged arena, replicated
    csr_nodes: jax.Array      # (L,) int32
    parent: jax.Array         # (N,) int32 — forest arrays, replicated
    entity_id: jax.Array      # (N,) int32
    child_offsets: jax.Array  # (N + 1,) int32
    child_index: jax.Array    # (C,) int32
    mesh: Mesh                # static
    axis: str                 # static

    _LEAVES = ("fingerprints", "temperature", "heads", "tree_shard",
               "tree_offset", "tree_nb", "csr_offsets", "csr_nodes",
               "parent", "entity_id", "child_offsets", "child_index")

    def tree_flatten(self):
        return (tuple(getattr(self, f) for f in self._LEAVES),
                (self.mesh, self.axis))

    @classmethod
    def tree_unflatten(cls, aux, children):
        return cls(*children, *aux)

    # --------------------------------------------------------------- sizes
    @property
    def num_shards(self) -> int:
        return int(self.mesh.shape[self.axis])

    @property
    def arena_rows_per_shard(self) -> int:
        return int(self.fingerprints.shape[0]) // self.num_shards

    @property
    def num_trees(self) -> int:
        return int(self.tree_shard.shape[0])

    @property
    def slots(self) -> int:
        return int(self.fingerprints.shape[-1])

    # ----------------------------------------------------------- threading
    def with_temperature(self, temperature: jax.Array) -> "ShardedBankState":
        """Thread an updated packed temperature forward (same contract as
        ``CFTDeviceState.with_temperature``)."""
        return dataclasses.replace(self, temperature=temperature)

    def sort_idle(self) -> "ShardedBankState":
        """Device-only idle-time bucket sort over every shard's arena at
        once (pure per-bucket slot reorder — sharding is preserved).  As
        with ``CFTDeviceState.sort_idle``: only for states with no host
        bank mirror; a host ``ShardedMaintenanceEngine`` sorts + restages
        instead so layouts never diverge."""
        f, t, h = sort_buckets_arena(self.fingerprints, self.temperature,
                                     self.heads)
        return dataclasses.replace(self, fingerprints=f, temperature=t,
                                   heads=h)


def stage_sharded_bank(sbank: ShardedBank, forest: EntityForest,
                       mesh: Mesh, axis: str = "model",
                       arena_rows: Optional[int] = None
                       ) -> ShardedBankState:
    """Place a host :class:`ShardedBank` on the mesh as a
    :class:`ShardedBankState` (packed arena blocks sharded over ``axis``,
    routing/CSR/forest replicated).  ``arena_rows`` forces a larger
    per-shard block than the tight minimum — used to compare against a
    live state whose padding an in-place commit could not shrink."""
    d = int(mesh.shape[axis])
    if d != sbank.num_shards:
        raise ValueError(f"bank has {sbank.num_shards} shards but mesh "
                         f"axis '{axis}' has {d} devices")
    fps, temp, heads = sbank.packed_tables(arena_rows=arena_rows)
    csr_off, csr_nodes = pad_csr(*sbank.merged_csr())
    blk = NamedSharding(mesh, P(axis, None))
    rep = NamedSharding(mesh, P())
    put_b = lambda a: jax.device_put(jnp.asarray(a), blk)     # noqa: E731
    put_r = lambda a: jax.device_put(jnp.asarray(a), rep)     # noqa: E731
    fa = CFTDeviceState._forest_arrays(forest)
    return ShardedBankState(
        fingerprints=put_b(fps), temperature=put_b(temp),
        heads=put_b(heads),
        tree_shard=put_r(sbank.tree_shard_map()),
        tree_offset=put_r(sbank.tree_arena_offsets().astype(np.int32)),
        tree_nb=put_r(sbank.tree_nb_map()),
        csr_offsets=put_r(csr_off),
        csr_nodes=put_r(csr_nodes if csr_nodes.size
                        else np.zeros(1, np.int32)),
        parent=put_r(fa["parent"]), entity_id=put_r(fa["entity_id"]),
        child_offsets=put_r(fa["child_offsets"]),
        child_index=put_r(fa["child_index"]),
        mesh=mesh, axis=axis)


def shard_bank(bank: FilterBank, forest: EntityForest, mesh: Mesh,
               axis: str = "model",
               tree_starts: Optional[np.ndarray] = None
               ) -> Tuple[ShardedBank, ShardedBankState]:
    """Partition + stage in one step; returns (host sbank, device state)."""
    sbank = bank.shard(num_shards=int(mesh.shape[axis]),
                       tree_starts=tree_starts)
    return sbank, stage_sharded_bank(sbank, forest, mesh, axis)


def plan_tenant_partition(weights: np.ndarray, registry,
                          num_shards: int) -> np.ndarray:
    """Shard ``tree_starts`` balanced by per-tree weight but snapped to
    the registry's tenant boundaries, so no tenant straddles two shards.

    A straddling tenant would make its eviction/reload a cross-shard
    transaction and its fault attribution ambiguous; with aligned
    boundaries every tenant lifecycle op stays a per-shard segment
    splice.  Needs at least ``num_shards`` boundary-delimited segments
    (tenant ranges plus any unowned gaps)."""
    from .bank import plan_partition
    w = np.asarray(weights, np.float64).ravel()
    cuts = {0, w.size}
    for name in registry.names:
        lo, hi = registry.trees(name)
        cuts.update((int(lo), int(hi)))
    bounds = np.asarray(sorted(cuts), np.int64)
    if bounds[0] < 0 or bounds[-1] > w.size:
        raise ValueError("tenant ranges exceed the tree count")
    seg_w = np.add.reduceat(np.maximum(w, 1e-9), bounds[:-1])
    seg_starts = plan_partition(seg_w, num_shards)
    return bounds[seg_starts.astype(np.int64)].astype(np.int32)


# ----------------------------------------------- incremental arena update
#
# The donated-buffer commit ops of the double-buffered restage
# (``repro.core.maintenance.commit_restage``): a maintenance cycle writes
# its delta straight into the live packed arena — only the owning shard's
# rows are touched, every non-owner block comes out byte-identical, and
# the whole update moves O(changed rows) host→device bytes instead of a
# shard repack.  Donation keeps the scatter in-place where the backend
# supports it; the pre-commit arrays are invalid either way.

@functools.partial(jax.jit, static_argnames=("mesh", "axis"),
                   donate_argnums=(0, 1, 2))
def sharded_apply_delta(fps: jax.Array, temp: jax.Array, heads: jax.Array,
                        rows: jax.Array, vf: jax.Array, vt: jax.Array,
                        vh: jax.Array, vkeep: jax.Array, shift: jax.Array,
                        mesh: Mesh, axis: str):
    """Per-shard in-place row scatter + merged-head-numbering shift.

    ``rows``/``v*`` are stacked per-shard payloads ``(D, Kpad[, S])`` in
    *local block* coordinates (sentinel rows land out of bounds and are
    dropped — a shard with no changes gets an all-sentinel lane);
    ``shift`` is the per-shard merged CSR row-id delta (an insert into
    shard d renumbers every later shard's merged rows — applied here as
    an elementwise add over occupied slots, zero host→device bytes).

    Like :func:`repro.core.bank.splice_arena_rows`, temperature
    max-merges on slots whose key the plan leaves in place — ``vkeep``
    is the plan-time ``staged fp == shadow fp`` mask (see there for why
    the donated fps must not be read for the guard) — so bumps that
    landed on device between plan and commit survive.
    """
    def local(f, t, h, r, lf, lt, lh, lk, s):
        h = jnp.where(h != NULL, h + s[0], h)
        r0 = r[0]
        live_t = jnp.where(lk[0], t[r0], 0)
        return (f.at[r0].set(lf[0], mode="drop"),
                t.at[r0].set(jnp.maximum(lt[0], live_t), mode="drop"),
                h.at[r0].set(lh[0], mode="drop"))

    blk = P(axis, None)
    fn = _shard_map(local, mesh=mesh,
                    in_specs=(blk, blk, blk, blk, P(axis, None, None),
                              P(axis, None, None), P(axis, None, None),
                              P(axis, None, None), P(axis)),
                    out_specs=(blk, blk, blk), check_rep=False)
    return fn(fps, temp, heads, rows, vf, vt, vh, vkeep, shift)


@functools.partial(jax.jit, static_argnames=("mesh", "axis"),
                   donate_argnums=(0, 1, 2))
def sharded_splice_segment(fps: jax.Array, temp: jax.Array,
                           heads: jax.Array, seg_f: jax.Array,
                           seg_t: jax.Array, seg_h: jax.Array,
                           owner: jax.Array, start: jax.Array,
                           mesh: Mesh, axis: str):
    """Owner-local segment splice via ``dynamic_update_slice`` inside
    ``shard_map``: the staged segment (the resized tree plus the shifted
    later trees of the same shard, padded with empty rows when a shrink
    leaves a stale tail) lands at ``start`` of the owning shard's packed
    block; every other shard returns its block untouched.  ``owner`` and
    ``start`` are traced scalars, so repeated splices at different
    positions reuse one compilation per segment length."""
    def local(f, t, h, sf, st, sh, ow, st0):
        me = jax.lax.axis_index(axis)

        def splice(_):
            dus = lambda a, s: jax.lax.dynamic_update_slice(  # noqa: E731
                a, s, (st0, jnp.int32(0)))
            return dus(f, sf), dus(t, st), dus(h, sh)

        return jax.lax.cond(me == ow, splice, lambda _: (f, t, h), None)

    blk = P(axis, None)
    fn = _shard_map(local, mesh=mesh,
                    in_specs=(blk, blk, blk, P(), P(), P(), P(), P()),
                    out_specs=(blk, blk, blk), check_rep=False)
    return fn(fps, temp, heads, seg_f, seg_t, seg_h, owner, start)


# ------------------------------------------------------- bank-axis lookup

def _bank_local_fn(axis: str, num_shards: int, num_trees: int, slots: int,
                   bump: bool, lookup_fn, capacity: int):
    """Build the shard-local body: route -> probe own arena -> route back.

    ``lookup_fn(fps, heads, row_offsets, masks, h)`` is the arena-probe
    contract (pure-jnp :func:`repro.core.lookup.lookup_arena` by default,
    or the Pallas ``cuckoo_lookup_arena_auto``): queries arrive on their
    owning shard already carrying their segment start and bucket mask, so
    heterogeneous per-tree bucket counts need no special casing.
    """
    probe = lookup_arena if lookup_fn is None else lookup_fn

    def local(fps_b, temp_b, heads_b, tree_shard, tree_off, tree_nb,
              tid, h):
        # ---- destination + local coordinates (replicated routing tables)
        tq = jnp.clip(tid, 0, num_trees - 1)
        valid = (tid >= 0) & (tid < num_trees)
        dest = jnp.where(valid, tree_shard[tq], 0).astype(jnp.int32)
        aoff = jnp.where(valid, tree_off[tq], 0).astype(jnp.int32)
        msk = jnp.where(valid, (tree_nb[tq] - 1).astype(jnp.uint32),
                        jnp.uint32(0))
        rank, (bh, bo, bm, bv) = _bucket_queries(
            dest, num_shards, capacity,
            ((h.astype(jnp.uint32), jnp.uint32(0)),
             (aoff, jnp.int32(0)), (msk, jnp.uint32(0)), (valid, False)))
        # ---- one exchange: every query lands on its owning shard
        qh = _exchange(bh, axis).reshape(-1)
        qo = _exchange(bo, axis).reshape(-1)
        qm = _exchange(bm, axis).reshape(-1)
        qv = _exchange(bv, axis).reshape(-1)
        # ---- shard-local probe of the owned (Apad, S) arena block
        res = probe(fps_b, heads_b, qo, qm, qh)
        hit = res.hit & qv
        head = jnp.where(hit, res.head, jnp.int32(NULL))
        if bump:   # owner-local: each tree's temperature has exactly 1 home
            temp_b = temp_b.at[qo + res.bucket, res.slot].add(
                hit.astype(temp_b.dtype))
        # ---- inverse exchange: results home to their source shard
        back = functools.partial(_route_back, dest=dest, rank=rank,
                                 axis=axis, num_shards=num_shards)
        return LookupResult(hit=back(hit), head=back(head),
                            bucket=back(res.bucket),
                            slot=back(res.slot)), temp_b

    return local


def _bank_local_fused_fn(axis: str, num_shards: int, num_trees: int,
                         capacity: int, max_locs: int):
    """Shard-local body for the *fused* owner probe: route -> one Pallas
    launch (probe + temperature bump + CSR location window, from the
    replicated CSR tables) on the owning shard -> route ``(hit,
    locations)`` home.  The hierarchy walk stays on the source shard
    (``finish_context`` over the replicated forest), so the route-back
    payload grows only by ``max_locs`` ints per query."""
    from ..kernels.cuckoo_lookup.ops import on_tpu
    from ..kernels.fused_retrieve.ops import (context_resident_bytes,
                                              fused_probe_locs,
                                              fused_row_tile)

    def local(fps_b, temp_b, heads_b, tree_shard, tree_off, tree_nb,
              csr_offsets, csr_nodes, tid, h):
        tq = jnp.clip(tid, 0, num_trees - 1)
        valid = (tid >= 0) & (tid < num_trees)
        dest = jnp.where(valid, tree_shard[tq], 0).astype(jnp.int32)
        aoff = jnp.where(valid, tree_off[tq], 0).astype(jnp.int32)
        msk = jnp.where(valid, (tree_nb[tq] - 1).astype(jnp.uint32),
                        jnp.uint32(0))
        rank, (bh, bo, bm, bv) = _bucket_queries(
            dest, num_shards, capacity,
            ((h.astype(jnp.uint32), jnp.uint32(0)),
             (aoff, jnp.int32(0)), (msk, jnp.uint32(0)), (valid, False)))
        qh = _exchange(bh, axis).reshape(-1)
        qo = _exchange(bo, axis).reshape(-1)
        qm = _exchange(bm, axis).reshape(-1)
        qv = _exchange(bv, axis).reshape(-1)
        interpret = not on_tpu()
        a, s = fps_b.shape
        rt = 0 if interpret else fused_row_tile(
            a, context_resident_bytes(a, s, csr_offsets.shape[0] - 1,
                                      csr_nodes.shape[0], 0, 0, True))
        hit, locs, temp_b = fused_probe_locs(
            fps_b, temp_b, heads_b, qo, qm, qv, qh, csr_offsets,
            csr_nodes, max_locs=max_locs, interpret=interpret, row_tile=rt,
            mxu=not interpret)
        back = functools.partial(_route_back, dest=dest, rank=rank,
                                 axis=axis, num_shards=num_shards)
        locs_home = _route_back_wide(locs, dest, rank, axis, num_shards)
        return back(hit), locs_home, temp_b

    return local


def _fused_lookup_core(state: ShardedBankState, tree_ids: jax.Array,
                       h: jax.Array, capacity: Optional[int],
                       max_locs: int
                       ) -> Tuple[jax.Array, jax.Array, jax.Array]:
    """Sharded fused probe core: returns ``(hit, locations, temperature)``
    with the CSR window already gathered on the owner shards."""
    mesh, axis = state.mesh, state.axis
    d = state.num_shards
    b = h.shape[0]
    pad = (-b) % d
    bl = (b + pad) // d
    cap = bl if capacity is None else min(capacity, bl)
    tid = jnp.pad(tree_ids.astype(jnp.int32), (0, pad),
                  constant_values=NULL)            # pad queries always miss
    hp = jnp.pad(h.astype(jnp.uint32), (0, pad))
    local = _bank_local_fused_fn(axis, d, state.num_trees, cap, max_locs)
    spec_b = P(axis, None)
    fn = _shard_map(
        local, mesh=mesh,
        in_specs=(spec_b, spec_b, spec_b, P(), P(), P(), P(), P(),
                  P(axis), P(axis)),
        out_specs=(P(axis), P(axis, None), spec_b),
        check_rep=False)                   # pallas_call: no replication rule
    hit, locs, temp = fn(state.fingerprints, state.temperature,
                         state.heads, state.tree_shard, state.tree_offset,
                         state.tree_nb, state.csr_offsets, state.csr_nodes,
                         tid, hp)
    return hit[:b], locs[:b], temp


def _lookup_core(state: ShardedBankState, tree_ids: jax.Array,
                 h: jax.Array, bump: bool, lookup_fn,
                 capacity: Optional[int]
                 ) -> Tuple[LookupResult, jax.Array]:
    mesh, axis = state.mesh, state.axis
    d = state.num_shards
    b = h.shape[0]
    pad = (-b) % d
    bl = (b + pad) // d
    cap = bl if capacity is None else min(capacity, bl)
    tid = jnp.pad(tree_ids.astype(jnp.int32), (0, pad),
                  constant_values=NULL)            # pad queries always miss
    hp = jnp.pad(h.astype(jnp.uint32), (0, pad))
    local = _bank_local_fn(axis, d, state.num_trees, state.slots, bump,
                           lookup_fn, cap)
    spec_b = P(axis, None)
    fn = _shard_map(
        local, mesh=mesh,
        in_specs=(spec_b, spec_b, spec_b, P(), P(), P(), P(axis), P(axis)),
        out_specs=(LookupResult(hit=P(axis), head=P(axis), bucket=P(axis),
                                slot=P(axis)), spec_b),
        # pallas_call has no replication rule; rep-check only costs us the
        # kernel probe path, so switch it off just there
        check_rep=lookup_fn is None)
    res, temp = fn(state.fingerprints, state.temperature, state.heads,
                   state.tree_shard, state.tree_offset, state.tree_nb,
                   tid, hp)
    return LookupResult(hit=res.hit[:b], head=res.head[:b],
                        bucket=res.bucket[:b], slot=res.slot[:b]), temp


@functools.partial(jax.jit, static_argnames=("mesh", "axis", "num_shards",
                                             "num_trees"))
def _routing_counts_jit(tree_shard: jax.Array, tid: jax.Array, mesh: Mesh,
                        axis: str, num_shards: int, num_trees: int):
    """First pass of the two-pass capacity protocol: each shard counts
    its outgoing queries per destination and one tiny ``all_to_all``
    exchanges the per-pair counts — O(D²) ints instead of the payload."""
    pad = (-tid.shape[0]) % num_shards
    tid = jnp.pad(tid.astype(jnp.int32), (0, pad), constant_values=NULL)

    def local(ts, tl):
        tq = jnp.clip(tl, 0, num_trees - 1)
        valid = (tl >= 0) & (tl < num_trees)
        # invalid/pad queries route to shard 0 and occupy buffer slots,
        # exactly as in the payload exchange — count them too
        dest = jnp.where(valid, ts[tq], 0).astype(jnp.int32)
        counts = jnp.zeros((num_shards,), jnp.int32).at[dest].add(1)
        recv = _exchange(counts.reshape(num_shards, 1), axis)
        return recv.reshape(1, num_shards)

    fn = _shard_map(local, mesh=mesh, in_specs=(P(), P(axis)),
                    out_specs=P(axis, None), check_rep=False)
    return fn(tree_shard, tid)


def routing_counts(state: ShardedBankState, tree_ids) -> np.ndarray:
    """(D, D) routed-query counts of this batch — entry ``[dst, src]`` is
    how many of source shard ``src``'s local queries (pad slots included)
    land on shard ``dst``.  Padding and counting both run device-side;
    the only host transfer is the O(D²) count readback that sizes the
    payload buffer."""
    tid = jnp.asarray(tree_ids).reshape(-1)
    counts = np.asarray(_routing_counts_jit(
        state.tree_shard, tid, state.mesh, state.axis, state.num_shards,
        state.num_trees))
    reg = get_registry()
    if reg.enabled:
        reg.counter("dist.count_exchanges",
                    "all-to-all routing-count passes").inc()
        reg.counter("dist.routed_queries",
                    "queries routed through the all-to-all "
                    "(pad slots included)").inc(int(counts.sum()))
        reg.gauge("dist.routing_max",
                  "worst per-(dst,src) routed count of the last batch"
                  ).set(int(counts.max()))
    return counts


def _pick_capacity(state: ShardedBankState, tree_ids,
                   capacity_factor: Optional[float]) -> Optional[int]:
    """Two-pass adaptive receive capacity for the routed all-to-all.

    ``None`` keeps the worst-case buffer (C = Bl: every local query to
    one shard — no count pass, can never overflow).  With a factor ``f``,
    the count exchange measures the batch's actual per-pair maximum:
    when it fits ``ceil(f·Bl)`` the factor-derived capacity is used (the
    fast path — a batch-independent static shape, so steady traffic
    never recompiles); when it would overflow, the buffer grows to the
    measured maximum instead (rounded up to a power of two to bound
    recompiles), replacing the old eager host-side pre-check that raised.
    """
    adapt = get_registry().counter(
        "dist.capacity", "all-to-all receive-capacity picks by path")
    if capacity_factor is None:
        adapt.inc(path="worst_case")
        return None
    d = state.num_shards
    b = int(jnp.asarray(tree_ids).size)    # shape metadata, no transfer
    bl = -(-b // d)
    fast = min(bl, max(1, int(np.ceil(bl * float(capacity_factor)))))
    worst = int(routing_counts(state, tree_ids).max())
    if worst <= fast:
        adapt.inc(path="fast")
        return fast
    adapt.inc(path="adapted")
    return min(bl, 1 << int(np.ceil(np.log2(max(1, worst)))))


@functools.partial(jax.jit, static_argnames=("lookup_fn", "capacity"))
def _sharded_lookup_jit(state: ShardedBankState, tree_ids: jax.Array,
                        h: jax.Array, lookup_fn=None,
                        capacity: Optional[int] = None) -> LookupResult:
    res, _ = _lookup_core(state, tree_ids, h, bump=False,
                          lookup_fn=lookup_fn, capacity=capacity)
    return res


def sharded_lookup_bank(state: ShardedBankState, tree_ids: jax.Array,
                        h: jax.Array, lookup_fn=None,
                        capacity_factor: Optional[float] = None
                        ) -> LookupResult:
    """All-to-all routed bank lookup; bit-identical to
    ``lookup_batch_ragged`` over the merged replicated arena.

    ``lookup_fn(fps, heads, row_offsets, masks, h)`` swaps in a different
    shard-local arena probe (e.g. the row-tiled Pallas kernel
    ``repro.kernels.cuckoo_lookup.cuckoo_lookup_arena_auto``) — usable
    regardless of heterogeneous per-tree bucket counts, since routing
    arrives per query.  ``capacity_factor`` shrinks the all-to-all
    receive buffer below the worst case via the two-pass count exchange
    (see :func:`_pick_capacity`: the factor is the fast path when the
    batch's measured per-pair counts fit, and the buffer adapts to the
    actual maximum when they don't — no overflow, no eager host
    pre-check).  Pure: temperature is not bumped (use
    :func:`sharded_retrieve_device` for serving).
    """
    capacity = _pick_capacity(state, tree_ids, capacity_factor)
    return _sharded_lookup_jit(state, tree_ids, h, lookup_fn=lookup_fn,
                               capacity=capacity)


@functools.partial(jax.jit,
                   static_argnames=("max_locs", "n", "lookup_fn",
                                    "capacity", "fused"))
def _sharded_retrieve_jit(state: ShardedBankState,
                          query_hashes: jax.Array,
                          query_trees: jax.Array,
                          max_locs: int = 4, n: int = 3,
                          lookup_fn=None,
                          capacity: Optional[int] = None,
                          fused: bool = False
                          ) -> DeviceRetrieval:
    if fused:
        hit, locs, temp = _fused_lookup_core(
            state, query_trees, query_hashes, capacity=capacity,
            max_locs=max_locs)
        return finish_context(state, hit, locs, temp,
                              max_locs=max_locs, n=n)
    res, temp = _lookup_core(state, query_trees, query_hashes, bump=True,
                             lookup_fn=lookup_fn, capacity=capacity)
    return gather_context(state, res, temp, max_locs=max_locs, n=n)


def sharded_retrieve_device(state: ShardedBankState,
                            query_hashes: jax.Array,
                            query_trees: Optional[jax.Array] = None,
                            max_locs: int = 4, n: int = 3,
                            lookup_fn=None,
                            capacity_factor: Optional[float] = None,
                            fused: bool = False) -> DeviceRetrieval:
    """Bank-axis sharded analogue of ``repro.core.retrieve_device``.

    The lookup routes through the all-to-all; temperature bumps land in
    the owning shard's packed arena during the probe (so the returned
    ``temperature`` keeps the sharded layout — thread it forward with
    ``state.with_temperature``); the CSR location gather and hierarchy
    windows run on the replicated arrays exactly as the replicated path.

    ``fused=True`` fuses probe + temperature bump + CSR location gather
    into one Pallas launch *on the owner shard* before the route-back
    all-to-all (the replicated CSR tables make the owner-side gather
    free of extra communication); only ``(hit, locations)`` travel home,
    and the hierarchy walk finishes on the source shard.  Bit-identical
    to the unfused path; mutually exclusive with ``lookup_fn``.
    """
    if fused and lookup_fn is not None:
        raise ValueError("fused=True embeds the probe; lookup_fn "
                         "cannot be combined with it")
    if query_trees is None:
        query_trees = jnp.zeros(query_hashes.shape, jnp.int32)
    capacity = _pick_capacity(state, query_trees, capacity_factor)
    return _sharded_retrieve_jit(state, query_hashes, query_trees,
                                 max_locs=max_locs, n=n,
                                 lookup_fn=lookup_fn, capacity=capacity,
                                 fused=fused)


# ------------------------------------------- legacy single-filter wrappers

def _filter_local_fn(axis: str, num_shards: int, nb_global: int,
                     nb_local: int, slots: int):
    """Shard-local body for the bucket-striped single filter: each query
    fans out to its two candidate stripes through the shared router, each
    stripe scans one bucket row, and the pair merges with i1 priority."""

    def local(fps_s, heads_s, h_l):
        bl = h_l.shape[0]
        fp, i1, i2 = hashing.candidate_buckets(h_l.astype(jnp.uint32),
                                               nb_global, jnp)
        # 2 routed probes per query: [all i1 probes ; all i2 probes]
        cand = jnp.concatenate([i1, i2]).astype(jnp.int32)
        dest = cand // nb_local                    # stripe == owning shard
        lb = cand % nb_local
        fp2 = jnp.tile(fp, 2)
        rank, (bb, bf) = _bucket_queries(
            dest, num_shards, 2 * bl,
            ((lb, jnp.int32(0)), (fp2, jnp.uint32(0))))
        qb = _exchange(bb, axis).reshape(-1)
        qf = _exchange(bf, axis).reshape(-1)
        rows = fps_s[qb]                           # (D*C, S)
        m = rows == qf[:, None]
        hit = jnp.any(m, axis=1)
        slot = jnp.argmax(m, axis=1).astype(jnp.int32)
        head = jnp.take_along_axis(heads_s[qb], slot[:, None],
                                   axis=1)[:, 0]
        back = functools.partial(_route_back, dest=dest, rank=rank,
                                 axis=axis, num_shards=num_shards)
        hit, head, slot = back(hit), back(head), back(slot)
        h1, h2 = hit[:bl], hit[bl:]
        # i1 priority — identical tie-breaking to match_rows' 2S concat
        return LookupResult(
            hit=h1 | h2,
            head=jnp.where(h1, head[:bl],
                           jnp.where(h2, head[bl:], jnp.int32(NULL))),
            bucket=jnp.where(h1 | ~h2, i1, i2).astype(jnp.int32),
            slot=jnp.where(h1, slot[:bl],
                           jnp.where(h2, slot[bl:], jnp.int32(0))))

    return local


def sharded_lookup(mesh: Mesh, axis: str, fingerprints: jax.Array,
                   heads: jax.Array, h: jax.Array) -> LookupResult:
    """Single-filter lookup with tables bucket-sharded over ``axis``.

    Thin wrapper over the bank-axis router: the D bucket stripes act as a
    degenerate D-tree bank (one "tree" per shard), each query routes to its
    two candidate stripes, and no replica combine exists — the old
    replicated-query pmax path is gone.  Bit-identical to
    ``lookup_batch``.
    """
    nb_global, slots = fingerprints.shape
    d = int(mesh.shape[axis])
    if nb_global % d:
        raise ValueError(f"bucket count {nb_global} not divisible by "
                         f"mesh axis size {d}")
    b = h.shape[0]
    pad = (-b) % d
    hp = jnp.pad(h.astype(jnp.uint32), (0, pad))
    local = _filter_local_fn(axis, d, nb_global, nb_global // d, slots)
    fn = _shard_map(
        local, mesh=mesh,
        in_specs=(P(axis, None), P(axis, None), P(axis)),
        out_specs=LookupResult(hit=P(axis), head=P(axis), bucket=P(axis),
                               slot=P(axis)))
    res = fn(fingerprints, heads, hp)
    return LookupResult(hit=res.hit[:b], head=res.head[:b],
                        bucket=res.bucket[:b], slot=res.slot[:b])


def shard_filter_tables(mesh: Mesh, axis: str, *tables: jax.Array
                        ) -> Tuple[jax.Array, ...]:
    """Place filter tables bucket-sharded on the mesh."""
    sharding = NamedSharding(mesh, P(axis, None))
    return tuple(jax.device_put(t, sharding) for t in tables)
