"""Atomic bank/state snapshots — the crash-recovery half of the
fault-tolerant serving story.

A snapshot is one directory (``snap_<step>``) holding a ``.npy`` file per
array leaf plus a ``manifest.json`` naming them, written with the same
tmp-then-``os.rename`` discipline as ``repro.training.checkpoint``: a
crash (or an injected ``snapshot-write`` fault) at any point leaves at
worst a stale ``tmp.*`` directory — the previous snapshot stays intact
and ``latest_snapshot`` never sees a half-written one.

What gets captured (always as host numpy, ``jax.device_get``-gathered —
works unchanged for sharded global arrays):

* the **host bank** (:class:`FilterBank` or :class:`ShardedBank`) — the
  source of truth every restage rebuilds from;
* the **maintenance bookkeeping** (``row_alive``/``row_hash`` per
  engine) — ``MaintenanceEngine.__init__`` cannot reconstruct tombstoned
  rows from the slots alone, so without it a restored bank would
  resurrect dead CSR rows;
* optionally the **device state** (:class:`CFTDeviceState` or
  :class:`ShardedBankState`) leaf-for-leaf, so restore is bit-identical
  to what was serving at snapshot time (including temperature) rather
  than a re-staged approximation.

Restore is elastic the same way checkpoint restore is: a sharded state
re-lands on any mesh whose axis matches the saved shard count via
``device_put`` with explicit shardings, and :func:`merge_sharded_bank`
flattens a sharded bank so it can be re-``shard()``-ed onto a different
device count (placement-preserving: ``shard`` slices, never rebuilds).
"""
from __future__ import annotations

import dataclasses
import json
import os
import shutil
from typing import Callable, Dict, List, Optional

import jax
import jax.numpy as jnp
import numpy as np
from jax.sharding import NamedSharding, PartitionSpec as P

from ..obs import get_registry
from . import hashing
from .bank import ColdTenant, FilterBank, ShardedBank
from .cuckoo import NULL
from .distributed import ShardedBankState
from .trag import CFTDeviceState

_SNAP_FMT = "snap_%08d"
_TMP_PREFIX = "tmp."
#: packed-arena leaves of a sharded state — placed P(axis, None); the
#: rest replicate
_PACKED_LEAVES = frozenset(("fingerprints", "temperature", "heads"))


def _jsonable(v):
    return v.item() if isinstance(v, np.generic) else v


def _bank_array_fields() -> List[str]:
    return [f.name for f in dataclasses.fields(FilterBank)
            if f.name not in ("num_trees", "slots", "build_stats")]


def _collect_bank(bank: FilterBank, prefix: str,
                  arrays: Dict[str, np.ndarray]) -> Dict:
    for name in _bank_array_fields():
        arrays[prefix + name] = np.asarray(getattr(bank, name))
    return {"num_trees": int(bank.num_trees), "slots": int(bank.slots),
            "build_stats": {k: _jsonable(v)
                            for k, v in bank.build_stats.items()}}


def _state_leaf_names(state) -> tuple:
    if isinstance(state, ShardedBankState):
        return ShardedBankState._LEAVES
    return tuple(f.name for f in dataclasses.fields(CFTDeviceState))


# ------------------------------------------------------------------ save

def save_snapshot(snap_dir: str, step: int, bank, state=None, maint=None,
                  extra: Optional[Dict] = None,
                  fault_hook: Optional[Callable[[str], None]] = None
                  ) -> str:
    """Write one atomic snapshot; returns the final directory path.

    ``fault_hook("snapshot-write")`` fires after every leaf and the
    manifest are on disk but *before* the rename — the injectable crash
    window that proves atomicity (the previous snapshot survives, the
    aborted tmp dir is swept).  A raise anywhere removes the tmp dir
    best-effort and propagates; the visible snapshot set is unchanged.
    """
    arrays: Dict[str, np.ndarray] = {}
    meta: Dict = {"extra": extra or {}}
    if isinstance(bank, ShardedBank):
        meta["kind"] = "sharded"
        meta["num_shards"] = bank.num_shards
        arrays["tree_starts"] = np.asarray(bank.tree_starts)
        meta["banks"] = [_collect_bank(b, f"bank{d}/", arrays)
                         for d, b in enumerate(bank.banks)]
    elif isinstance(bank, FilterBank):
        meta["kind"] = "flat"
        meta["banks"] = [_collect_bank(bank, "bank0/", arrays)]
    else:
        raise TypeError(f"cannot snapshot bank of type {type(bank)}")
    if state is not None:
        if isinstance(state, ShardedBankState):
            meta["state"] = {"layout": "sharded", "axis": state.axis,
                             "num_shards": state.num_shards}
        else:
            meta["state"] = {"layout": "replicated"}
        for n in _state_leaf_names(state):
            arrays[f"state/{n}"] = np.asarray(
                jax.device_get(getattr(state, n)))
    if maint is not None:
        engines = getattr(maint, "engines", None)
        if engines is None:
            engines = [maint]
        meta["maint_engines"] = len(engines)
        for d, e in enumerate(engines):
            arrays[f"maint{d}/row_alive"] = np.asarray(e.row_alive)
            arrays[f"maint{d}/row_hash"] = np.asarray(e.row_hash)

    os.makedirs(snap_dir, exist_ok=True)
    final = os.path.join(snap_dir, _SNAP_FMT % int(step))
    tmp = os.path.join(snap_dir, f"{_TMP_PREFIX}{int(step)}")
    if os.path.exists(tmp):
        shutil.rmtree(tmp)
    os.makedirs(tmp)
    try:
        leaves = []
        for name, arr in arrays.items():
            fn = name.replace("/", "__") + ".npy"
            np.save(os.path.join(tmp, fn), np.ascontiguousarray(arr))
            leaves.append({"name": name, "file": fn,
                           "dtype": str(arr.dtype),
                           "shape": list(arr.shape)})
        manifest = {"step": int(step), "leaves": leaves, "meta": meta}
        with open(os.path.join(tmp, "manifest.json"), "w") as f:
            json.dump(manifest, f)
        if fault_hook is not None:
            fault_hook("snapshot-write")
        if os.path.exists(final):
            shutil.rmtree(final)
        os.rename(tmp, final)
    except BaseException:
        shutil.rmtree(tmp, ignore_errors=True)
        raise
    get_registry().counter("snapshot.saved",
                           "bank/state snapshots written").inc()
    return final


def list_snapshots(snap_dir: str) -> List[int]:
    if not os.path.isdir(snap_dir):
        return []
    steps = []
    for d in os.listdir(snap_dir):
        if d.startswith("snap_"):
            try:
                steps.append(int(d.split("_", 1)[1]))
            except ValueError:
                pass
    return sorted(steps)


def latest_snapshot(snap_dir: str) -> Optional[int]:
    steps = list_snapshots(snap_dir)
    return steps[-1] if steps else None


_TENANT_PREFIX = "tenant_"


def save_tenant(snap_dir: str, cold: ColdTenant,
                fault_hook: Optional[Callable[[str], None]] = None
                ) -> str:
    """Persist one evicted/offboarded tenant's :class:`ColdTenant`
    atomically (same tmp-then-rename discipline as :func:`save_snapshot`,
    same ``snapshot-write`` fault window) — the durable half of
    offboarding: ``offboard_tenant`` → ``save_tenant`` now,
    ``load_tenant`` → ``onboard_tenant`` later, possibly in another
    process.  The ``tenant_<name>`` directory sits beside the ``snap_*``
    ones; :func:`list_snapshots` never confuses the two, and
    :func:`cleanup_snapshots`' tmp sweep covers crashed tenant writes
    too."""
    os.makedirs(snap_dir, exist_ok=True)
    final = os.path.join(snap_dir, _TENANT_PREFIX + cold.name)
    tmp = os.path.join(snap_dir, f"{_TMP_PREFIX}tenant.{cold.name}")
    if os.path.exists(tmp):
        shutil.rmtree(tmp)
    os.makedirs(tmp)
    try:
        arrays = {"tree_nb": cold.tree_nb, "num_items": cold.num_items}
        arrays.update({f"tables/{k}": v for k, v in cold.tables.items()})
        leaves = []
        for name, arr in arrays.items():
            fn = name.replace("/", "__") + ".npy"
            np.save(os.path.join(tmp, fn), np.ascontiguousarray(arr))
            leaves.append({"name": name, "file": fn})
        manifest = {"tenant": cold.name, "lo": int(cold.lo),
                    "hi": int(cold.hi), "leaves": leaves}
        with open(os.path.join(tmp, "manifest.json"), "w") as f:
            json.dump(manifest, f)
        if fault_hook is not None:
            fault_hook("snapshot-write")
        if os.path.exists(final):
            shutil.rmtree(final)
        os.rename(tmp, final)
    except BaseException:
        shutil.rmtree(tmp, ignore_errors=True)
        raise
    get_registry().counter("snapshot.tenants_saved",
                           "per-tenant cold snapshots written").inc(
                               tenant=cold.name)
    return final


def load_tenant(snap_dir: str, name: str) -> ColdTenant:
    """Load a :func:`save_tenant` snapshot back to a host
    :class:`ColdTenant`, ready for ``onboard_tenant`` /
    ``TenantRegistry.reload``."""
    path = os.path.join(snap_dir, _TENANT_PREFIX + name)
    with open(os.path.join(path, "manifest.json")) as f:
        manifest = json.load(f)
    arrays = {l["name"]: np.load(os.path.join(path, l["file"]))
              for l in manifest["leaves"]}
    tables = {n.split("/", 1)[1]: a for n, a in arrays.items()
              if n.startswith("tables/")}
    return ColdTenant(name=manifest["tenant"], lo=int(manifest["lo"]),
                      hi=int(manifest["hi"]),
                      tree_nb=arrays["tree_nb"].astype(np.int32),
                      num_items=arrays["num_items"].astype(np.int32),
                      tables=tables)


def list_tenants(snap_dir: str) -> List[str]:
    """Names with a persisted :func:`save_tenant` snapshot."""
    if not os.path.isdir(snap_dir):
        return []
    return sorted(d[len(_TENANT_PREFIX):] for d in os.listdir(snap_dir)
                  if d.startswith(_TENANT_PREFIX))


def cleanup_snapshots(snap_dir: str, keep_last: int = 3) -> None:
    """Prune old snapshots and sweep stale ``tmp.*`` dirs left by a
    crashed (or fault-injected) write."""
    steps = list_snapshots(snap_dir)
    drop = steps[:-keep_last] if keep_last > 0 else steps
    for s in drop:
        shutil.rmtree(os.path.join(snap_dir, _SNAP_FMT % s),
                      ignore_errors=True)
    if os.path.isdir(snap_dir):
        for d in os.listdir(snap_dir):
            if d.startswith(_TMP_PREFIX):
                shutil.rmtree(os.path.join(snap_dir, d),
                              ignore_errors=True)


# --------------------------------------------------------------- restore

@dataclasses.dataclass
class RestoredSnapshot:
    """Host-side view of one snapshot: the restored bank, the per-engine
    maintenance bookkeeping, and the raw device-state leaves (rebuilt
    into a device state by :func:`restore_state`)."""
    step: int
    path: str
    bank: object                       # FilterBank | ShardedBank
    row_alive: List[np.ndarray]
    row_hash: List[np.ndarray]
    state_leaves: Dict[str, np.ndarray]
    state_meta: Dict
    meta: Dict


def restore_snapshot(snap_dir: str,
                     step: Optional[int] = None) -> RestoredSnapshot:
    """Load a snapshot (latest by default) back to host numpy."""
    if step is None:
        step = latest_snapshot(snap_dir)
        if step is None:
            raise FileNotFoundError(f"no snapshots under {snap_dir}")
    path = os.path.join(snap_dir, _SNAP_FMT % int(step))
    with open(os.path.join(path, "manifest.json")) as f:
        manifest = json.load(f)
    arrays = {l["name"]: np.load(os.path.join(path, l["file"]))
              for l in manifest["leaves"]}
    meta = manifest["meta"]
    field_names = _bank_array_fields()
    banks = []
    for d, aux in enumerate(meta["banks"]):
        kw = {n: arrays[f"bank{d}/{n}"] for n in field_names}
        banks.append(FilterBank(num_trees=int(aux["num_trees"]),
                                slots=int(aux["slots"]),
                                build_stats=dict(aux["build_stats"]), **kw))
    if meta["kind"] == "sharded":
        bank: object = ShardedBank(tree_starts=arrays["tree_starts"],
                                   banks=banks)
    else:
        bank = banks[0]
    n_eng = int(meta.get("maint_engines", 0))
    return RestoredSnapshot(
        step=int(manifest["step"]), path=path, bank=bank,
        row_alive=[arrays[f"maint{d}/row_alive"] for d in range(n_eng)],
        row_hash=[arrays[f"maint{d}/row_hash"] for d in range(n_eng)],
        state_leaves={n.split("/", 1)[1]: a for n, a in arrays.items()
                      if n.startswith("state/")},
        state_meta=meta.get("state", {}), meta=meta)


def restore_state(snap: RestoredSnapshot, mesh=None,
                  axis: Optional[str] = None):
    """Rebuild the snapshot's device state bit-identically.

    Replicated snapshots land as a fresh :class:`CFTDeviceState`.
    Sharded snapshots need a mesh whose ``axis`` size equals the saved
    shard count; leaves re-land via ``device_put`` with explicit
    shardings (the checkpoint-restore elastic pattern — any mesh of the
    right axis size works, not just the one that wrote the snapshot).
    For a *different* shard count, restage from the bank instead:
    ``merge_sharded_bank(snap.bank).shard(D')``.
    """
    if not snap.state_meta:
        raise ValueError("snapshot carries no device state")
    if snap.state_meta["layout"] == "replicated":
        # copy: the leaves stay visible on the RestoredSnapshot, and a
        # zero-copy wrap would alias them into the serving state
        return CFTDeviceState(**{n: jnp.array(a, copy=True)
                                 for n, a in snap.state_leaves.items()})
    axis = axis or snap.state_meta["axis"]
    if mesh is None:
        raise ValueError("restoring a sharded state needs a mesh")
    d = int(mesh.shape[axis])
    if d != int(snap.state_meta["num_shards"]):
        raise ValueError(
            f"mesh axis {axis!r} has {d} devices but the snapshot was "
            f"taken over {snap.state_meta['num_shards']} shards; "
            f"re-shard elastically from the bank instead "
            f"(merge_sharded_bank(snap.bank).shard({d}))")
    blk = NamedSharding(mesh, P(axis, None))
    rep = NamedSharding(mesh, P())
    leaves = {n: jax.device_put(jnp.asarray(a),
                                blk if n in _PACKED_LEAVES else rep)
              for n, a in snap.state_leaves.items()}
    return ShardedBankState(**leaves, mesh=mesh, axis=axis)


def apply_maint_bookkeeping(maint, snap: RestoredSnapshot) -> None:
    """Overwrite a freshly constructed maintenance engine's liveness
    bookkeeping with the snapshot's — required after restore because
    ``__init__`` marks every CSR row alive (it cannot see tombstones)."""
    engines = getattr(maint, "engines", None)
    if engines is None:
        engines = [maint]
    if len(engines) != len(snap.row_alive):
        raise ValueError(f"snapshot has bookkeeping for "
                         f"{len(snap.row_alive)} engines, got "
                         f"{len(engines)}")
    for e, alive, hs in zip(engines, snap.row_alive, snap.row_hash):
        if alive.shape[0] != e.bank.num_rows:
            raise ValueError("bookkeeping row count does not match bank")
        e.row_alive = alive.astype(bool).copy()
        e.row_hash = hs.astype(np.uint32).copy()


def merge_sharded_bank(sbank: ShardedBank) -> FilterBank:
    """Flatten a sharded bank back to one global :class:`FilterBank` —
    the elastic re-shard path (``merge(...).shard(D')`` moves a snapshot
    between device counts).  The exact inverse of ``FilterBank.shard``:
    arenas concatenate with offset shifts, local CSR row ids lift to the
    canonical merged (shard-major) numbering, slot placement is copied
    byte-for-byte — so a restage of the merged bank answers identically
    to the sharded original.
    """
    banks = sbank.banks
    abase = np.cumsum([0] + [b.total_buckets for b in banks])
    rbase = np.cumsum([0] + [b.num_rows for b in banks])
    bucket_offsets = np.concatenate(
        [b.bucket_offsets[:-1].astype(np.int64) + abase[d]
         for d, b in enumerate(banks)]
        + [np.asarray([abase[-1]], np.int64)])
    heads = np.concatenate(
        [np.where(b.fingerprints != hashing.EMPTY_FP,
                  b.heads + np.int32(rbase[d]),
                  NULL).astype(np.int32) for d, b in enumerate(banks)])
    off = np.zeros(int(rbase[-1]) + 1, np.int32)
    pos = 1
    for b in banks:
        lens = np.diff(b.csr_offsets.astype(np.int64))
        off[pos:pos + lens.size] = lens
        pos += lens.size
    np.cumsum(off, out=off)
    return FilterBank(
        num_trees=sbank.num_trees,
        tree_nb=np.concatenate([b.tree_nb for b in banks]),
        bucket_offsets=bucket_offsets,
        slots=sbank.slots,
        fingerprints=np.concatenate([b.fingerprints for b in banks]),
        temperature=np.concatenate([b.temperature for b in banks]),
        heads=heads,
        entity_ids=np.concatenate([b.entity_ids for b in banks]),
        stored_hash=np.concatenate([b.stored_hash for b in banks]),
        csr_offsets=off,
        csr_nodes=np.concatenate(
            [b.csr_nodes for b in banks]).astype(np.int32),
        row_tree=np.concatenate(
            [b.row_tree + np.int32(sbank.tree_starts[d])
             for d, b in enumerate(banks)]).astype(np.int32),
        row_entity=np.concatenate([b.row_entity for b in banks]),
        num_items=np.concatenate([b.num_items for b in banks]),
        build_stats=dict(banks[0].build_stats))


# ---------------------------------------------------------------- writer

class SnapshotWriter:
    """Commit-driven snapshot cadence for a serving session.

    ``note_commit(state, maint)`` is called by the session after every
    *applied* maintenance commit — the one moment bank and device state
    are guaranteed in sync, so a restore that rebuilds the maintenance
    engine over the restored bank starts from a consistent shadow.
    Every ``every``-th commit writes a snapshot and prunes to
    ``keep_last``.  Writes are synchronous (host copies + ``.npy``
    writes) but a write *failure* never propagates into serving: it is
    counted (``snapshot.failures``), latched on ``last_error``, and the
    commit that triggered it still stands.
    """

    def __init__(self, snap_dir: str, every: int = 1, keep_last: int = 3,
                 fault_hook: Optional[Callable[[str], None]] = None):
        if every < 1:
            raise ValueError("snapshot cadence must be >= 1 commit")
        self.snap_dir = snap_dir
        self.every = every
        self.keep_last = keep_last
        self._fault = fault_hook
        self.commits = 0
        self.saved = 0
        self.last_path: Optional[str] = None
        self.last_error: Optional[BaseException] = None
        m = get_registry()
        self._c_failures = m.counter(
            "snapshot.failures", "snapshot writes that raised (by error)")

    def note_commit(self, state, maint) -> Optional[str]:
        self.commits += 1
        if self.commits % self.every:
            return None
        bank = getattr(maint, "sbank", None)
        if bank is None:
            bank = maint.bank
        try:
            path = save_snapshot(self.snap_dir, self.commits, bank,
                                 state=state, maint=maint,
                                 fault_hook=self._fault)
        except Exception as exc:      # serving must outlive a bad disk
            self.last_error = exc
            self._c_failures.inc(error=type(exc).__name__)
            return None
        self.saved += 1
        self.last_path = path
        if self.keep_last:
            cleanup_snapshots(self.snap_dir, self.keep_last)
        return path
