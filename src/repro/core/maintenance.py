"""Dynamic bank maintenance — incremental updates on a live FilterBank.

The paper sells the cuckoo filter over Bloom variants because it "supports
rapid membership queries and dynamic updates"; this module supplies the
*dynamic* half for the many-tree bank.  A built ``FilterBank`` is immutable
everywhere else in the codebase — any change used to mean a full vectorized
rebuild.  ``MaintenanceEngine`` mutates the live bank in place instead:

* **insert** — queued ``(tree, entity, nodes)`` rows append to the bank CSR
  arena and batch-place through ``bulk_place`` confined to each tree's
  arena segment, with the scalar kick chain as eviction fallback;
* **delete** — exact stored-hash slot removal (the host keeps the original
  32-bit hash per slot, so maintenance never deletes a fingerprint-colliding
  neighbour) with CSR row tombstoning; tombstones are reclaimed by a
  threshold-triggered **compaction** that rebuilds the CSR arena and remaps
  the slot payloads;
* **expand** — when one tree outgrows its bucket count, **only that tree's
  arena segment restages** at double ``nb_t`` (``_restage_tree``): the
  ragged layout gives every tree an independent power-of-two bucket count,
  so the segment splice shifts ``bucket_offsets`` after the hot tree and
  leaves every other segment byte-identical — no bank-wide (or, sharded,
  shard-wide) doubling, and no CSR renumbering.  Restage preserves slot
  temperatures.

Closing the paper's temperature feedback loop: the engine *harvests* device
temperature after each query batch (``absorb`` →
``FilterBank.absorb_temperature``), integrates the bump count, and a trigger
policy (``sort_threshold`` new bumps) schedules the idle-time adaptive sort
— host-side here, ``sort_buckets_arena`` on device — so hot entities
migrate to slot 0 and resolve on the first probe.

``maintain()`` is the serving engine's idle-time hook: absorb → apply
pending delta → compact if worthwhile → shrink a cold tree → sort if hot
enough, returning a ``MaintenanceReport`` whose ``changed`` flag tells the
caller to restage its ``CFTDeviceState`` from the mutated bank.

**Zero-pause restage.**  The synchronous restage (``from_bank`` /
``stage_sharded_bank`` after every changed cycle) re-ships the whole arena
even when one delta touched a handful of slots.  The engine therefore
keeps a *shadow* — a host copy of the content last staged to device — and
``plan_restage()`` diffs the mutated bank against it, classifying the
cycle as

* **delta** (splice-only): geometry unchanged — stage only the changed
  arena rows (plus any appended CSR rows) for an in-place donated scatter;
* **segment**: exactly one tree's ``nb_t`` changed (``expand_tree`` /
  ``shrink_tree``) — stage that tree's new segment for a device-side
  splice, every other segment's bytes ride along untouched;
* **full**: compaction (CSR renumbered) or multi-tree geometry change —
  fall back to a from-scratch restage.

``commit_restage(state, plan, engine, forest)`` applies the plan to a live
``CFTDeviceState`` / ``ShardedBankState`` — the serving layer splits this
into ``prepare_maintenance()`` (host planning + payload staging,
overlappable with in-flight batches) and ``commit_maintenance()`` (the
O(changed-bytes) splice + atomic state swap).
"""
from __future__ import annotations

import dataclasses
import threading
import time
from typing import Dict, List, Optional, Sequence, Tuple, Union

import numpy as np

from . import hashing
from .bank import (DEFAULT_LOAD_TARGET, EMPTY_TREE_NB, FilterBank,
                   ShardedBank, _pick_tree_buckets, _scalar_insert,
                   build_bank_from_rows, estimate_fpr, pad_csr)
from .cuckoo import (DEFAULT_LOAD_THRESHOLD, DEFAULT_MAX_KICKS, NULL,
                     bulk_place)
from ..obs import Tracer, get_registry

Key = Union[str, int]              # entity name or 32-bit entity hash


def _as_hash(key: Key) -> int:
    return int(hashing.entity_hash(key)) if isinstance(key, str) \
        else int(np.uint32(key))


@dataclasses.dataclass
class BankDelta:
    """Pending mutations, recorded until the next idle window.

    Within one delta, deletes apply before inserts; inserting a key that is
    already live replaces it (old CSR row tombstoned).  Queue order between
    two operations on the *same* key in the same phase is collapsed to the
    last one queued — callers needing strict sequential semantics apply
    between ops.
    """
    inserts: List[Tuple[int, int, int, List[int]]] = \
        dataclasses.field(default_factory=list)   # (tree, hash, eid, nodes)
    deletes: List[Tuple[int, int]] = \
        dataclasses.field(default_factory=list)   # (tree, hash)

    def __len__(self) -> int:
        return len(self.inserts) + len(self.deletes)

    def __bool__(self) -> bool:
        return len(self) > 0


@dataclasses.dataclass
class MaintenanceReport:
    """What one idle-time maintenance pass did."""
    absorbed_bumps: int = 0
    inserted: int = 0
    deleted: int = 0
    replaced: int = 0
    missed_deletes: int = 0
    expansions: int = 0
    shrinks: int = 0
    compacted: bool = False
    sorted: bool = False

    @property
    def changed(self) -> bool:
        """True when bank tables/CSR mutated — device state needs restage."""
        return bool(self.inserted or self.deleted or self.replaced
                    or self.expansions or self.shrinks or self.compacted
                    or self.sorted)


# --------------------------------------------------- maintenance breaker

class MaintenanceBreaker:
    """Circuit breaker + retry backoff for the maintenance fault domain.

    States (surfaced as the ``maint.breaker_state`` gauge — 0 closed,
    1 half-open, 2 open):

    * **closed** — normal operation.  After a failure, retries are gated
      by exponential backoff (``backoff * 2**(k-1)``, capped at
      ``backoff_max``, where k is the consecutive-failure count).
    * **open** — tripped after ``threshold`` consecutive failures (or a
      failed half-open probe).  Maintenance is disabled — the engine
      degrades to serve-only mode (stale but correct answers from the
      last committed state) until ``cooldown`` seconds pass.
    * **half-open** — one probe attempt is allowed after the cooldown; a
      success closes the breaker, a failure re-opens it.

    Time is always passed in (``now``) so a fake clock drives the state
    machine deterministically in tests.  Not locked — the coordinator
    already serializes the maintenance lifecycle under its own lock.

    ``tenant`` scopes the breaker to one tenant's maintenance fault
    domain: its state surfaces as ``tenant.breaker_state{tenant=}`` and
    its failures label ``maint.failures{tenant=,phase=}``, so one noisy
    tenant degrading to serve-only is attributable from the metrics
    snapshot alone.
    """

    CLOSED, HALF_OPEN, OPEN = "closed", "half_open", "open"
    _GAUGE = {CLOSED: 0, HALF_OPEN: 1, OPEN: 2}

    def __init__(self, threshold: int = 3, cooldown: float = 5.0,
                 backoff: float = 0.05, backoff_max: float = 2.0,
                 tenant: Optional[str] = None):
        self.threshold = threshold
        self.cooldown = cooldown
        self.backoff = backoff
        self.backoff_max = backoff_max
        self.tenant = tenant
        self.failures = 0                       # consecutive
        self.state = self.CLOSED
        self._last_failure_t: Optional[float] = None
        self._set_state(self.CLOSED)

    def _set_state(self, state: str) -> None:
        self.state = state
        if self.tenant is None:
            get_registry().gauge(
                "maint.breaker_state",
                "maintenance breaker: 0 closed, 1 half-open, 2 open").set(
                    self._GAUGE[state])
        else:
            get_registry().gauge(
                "tenant.breaker_state",
                "per-tenant maintenance breaker: 0 closed, 1 half-open, "
                "2 open (serve-only)").set(self._GAUGE[state],
                                           tenant=self.tenant)

    def retry_delay(self) -> float:
        """Current exponential-backoff delay (closed state, after k
        consecutive failures)."""
        if self.failures == 0:
            return 0.0
        return min(self.backoff * 2 ** (self.failures - 1),
                   self.backoff_max)

    def allow(self, now: float) -> bool:
        """May a maintenance attempt start at ``now``?  Transitions
        open → half-open once the cooldown elapses."""
        if self.state == self.OPEN:
            if self._last_failure_t is not None and \
                    now - self._last_failure_t >= self.cooldown:
                self._set_state(self.HALF_OPEN)
                return True
            return False
        if self.failures and self._last_failure_t is not None and \
                now - self._last_failure_t < self.retry_delay():
            return False                         # still backing off
        return True

    def record_failure(self, now: float, phase: str) -> None:
        self.failures += 1
        self._last_failure_t = now
        c = get_registry().counter(
            "maint.failures",
            "maintenance prepare/commit failures by phase (and tenant, "
            "when attributable)")
        if self.tenant is None:
            c.inc(phase=phase)
        else:
            c.inc(phase=phase, tenant=self.tenant)
        if self.state == self.HALF_OPEN or self.failures >= self.threshold:
            self._set_state(self.OPEN)

    def spawn(self, tenant: str) -> "MaintenanceBreaker":
        """A fresh breaker with this one's schedule, scoped to a tenant —
        how the coordinator derives per-tenant fault domains from its
        template breaker."""
        return MaintenanceBreaker(
            threshold=self.threshold, cooldown=self.cooldown,
            backoff=self.backoff, backoff_max=self.backoff_max,
            tenant=tenant)

    def record_success(self) -> None:
        self.failures = 0
        self._last_failure_t = None
        if self.state != self.CLOSED:
            self._set_state(self.CLOSED)


# ------------------------------------------------ double-buffered restage

_SCATTER_PAD = 256      # scatter payloads round up to this (shape-stable jit)


@dataclasses.dataclass
class _Shadow:
    """Host copy of the content last staged to device (the three staged
    arena tables plus the geometry/CSR markers the planner diffs against).
    ``compactions`` snapshots the engine's counter: a compaction renumbers
    CSR rows, which no incremental splice can express."""
    fingerprints: np.ndarray
    temperature: np.ndarray
    heads: np.ndarray
    tree_nb: np.ndarray
    bucket_offsets: np.ndarray
    num_rows: int
    compactions: int


@dataclasses.dataclass
class _HostPlan:
    """Planner classification before payload staging (numpy only)."""
    kind: str                                   # none | delta | segment | full
    rows: Optional[np.ndarray] = None           # changed arena rows, new coords
    keep: Optional[np.ndarray] = None           # (k, S) bool — staged fp ==
    #   shadow (= live device) fp, the commit-time temperature merge guard
    seg: Optional[Tuple[int, int, int, int]] = None   # (tree, lo, hi_old, hi_new)
    csr_appended: bool = False                  # CSR rows grew since staging


@dataclasses.dataclass
class PendingRestage:
    """A staged incremental restage for a replicated ``CFTDeviceState``.

    Produced by :meth:`MaintenanceEngine.plan_restage` (host diff against
    the shadow + async payload staging via ``jnp.asarray``), consumed by
    :func:`commit_restage`.  ``rows`` is sentinel-padded to a
    ``_SCATTER_PAD`` multiple (sentinel = arena rows → dropped by the
    scatter) so commit jit-compiles per payload *bucket*, not per cycle.
    """
    kind: str = "none"                  # none | delta | segment | full
    rows: Optional[object] = None       # (Kpad,) int32 — changed arena rows
    val_fps: Optional[object] = None    # (Kpad, S) staged row contents
    val_temp: Optional[object] = None
    val_heads: Optional[object] = None
    val_keep: Optional[object] = None   # (Kpad, S) bool — temp merge guard
    changed_rows: int = 0               # true (unpadded) count
    seg_tree: int = -1                  # segment splice: which tree resized
    seg_lo: int = 0                     # arena rows [seg_lo, seg_hi_old) out,
    seg_hi_old: int = 0                 # the staged segment in
    seg_fps: Optional[object] = None    # (nb_new, S)
    seg_temp: Optional[object] = None
    seg_heads: Optional[object] = None
    tree_nb: Optional[np.ndarray] = None          # new geometry (host)
    bucket_offsets: Optional[np.ndarray] = None
    csr_offsets: Optional[object] = None   # staged full CSR (replicated,
    csr_nodes: Optional[object] = None     # O(rows) — None when unchanged)


@dataclasses.dataclass
class PendingShardedRestage:
    """A staged incremental restage for a packed ``ShardedBankState``.

    Per-shard scatter payloads are stacked ``(D, Kpad[, S])`` so one
    ``shard_map`` applies every shard's delta at once (row sentinel is out
    of every block's bounds → dropped); ``head_shift`` carries the merged
    row-numbering shift per shard (an insert into shard d renumbers every
    later shard's merged CSR rows — applied as an in-place elementwise
    add, zero host→device bytes); ``segments`` lists owner-local
    ``dynamic_update_slice`` splices for resized tree segments.  The
    replicated routing tables / merged CSR restage wholesale when they
    changed — they are O(T) / O(rows), not O(arena).
    """
    kind: str = "none"                  # none | splice | full
    rows: Optional[object] = None       # (D, Kpad) int32 local block rows
    val_fps: Optional[object] = None    # (D, Kpad, S)
    val_temp: Optional[object] = None
    val_heads: Optional[object] = None  # merged numbering (new bases)
    val_keep: Optional[object] = None   # (D, Kpad, S) bool — temp merge guard
    head_shift: Optional[object] = None  # (D,) int32 or None when all-zero
    segments: List[Tuple[int, int, object, object, object]] = \
        dataclasses.field(default_factory=list)  # (owner, start, f, t, h)
    new_arena_rows: Optional[List[int]] = None   # per-shard A_d after
    tree_offset: Optional[object] = None   # replicated routing tables when
    tree_nb: Optional[object] = None       # geometry changed (host arrays
    csr_offsets: Optional[object] = None   # until warm places them on the
    csr_nodes: Optional[object] = None     # mesh; merged CSR when rows grew)
    changed_rows: int = 0


_TABLES = ("fingerprints", "temperature", "heads", "entity_ids",
           "stored_hash")


class MaintenanceEngine:
    """Incremental insert/delete/expand + temperature-driven sort policy
    over a live :class:`FilterBank`.

    The engine owns the bank's liveness bookkeeping: ``row_alive`` marks
    CSR rows still referenced by a filter slot, ``row_hash`` keeps each
    row's original entity hash (recovered from the built slots) so a
    restage or compaction can re-home every live row without the forest.
    Compaction renumbers CSR rows — previously returned row ids are
    invalidated, node lists (``walk_row``) are preserved exactly.
    Tree-local expansion (``expand_tree`` / automatic overflow handling)
    never renumbers rows: it splices a doubled segment into the arena and
    leaves every other tree's slots byte-identical.
    """

    def __init__(self, bank: FilterBank, seed: int = 0x5EED,
                 sort_threshold: int = 256,
                 load_threshold: float = DEFAULT_LOAD_THRESHOLD,
                 compact_min_dead: int = 32,
                 compact_dead_frac: float = 0.25,
                 max_kicks: int = DEFAULT_MAX_KICKS,
                 shrink_load: Optional[float] = None):
        self.bank = bank
        self.delta = BankDelta()
        self.sort_threshold = sort_threshold
        self.load_threshold = load_threshold
        self.compact_min_dead = compact_min_dead
        self.compact_dead_frac = compact_dead_frac
        self.max_kicks = max_kicks
        # load factor below which maintain() halves a cold tree's nb
        # (None = auto-shrink off; shrink_tree(force=True) always works)
        self.shrink_load = shrink_load
        self._seed = seed
        self._rng = np.random.default_rng(seed)
        self.bumps_since_sort = 0
        self.stats: Dict[str, int] = {
            "inserted": 0, "deleted": 0, "replaced": 0,
            "missed_deletes": 0, "expansions": 0, "shrinks": 0,
            "compactions": 0, "sorts": 0, "absorbed_bumps": 0}
        r = bank.num_rows
        self.row_alive = np.ones(r, dtype=bool)
        self.row_hash = np.zeros(r, dtype=np.uint32)
        occ = bank.fingerprints != hashing.EMPTY_FP
        self.row_hash[bank.heads[occ]] = bank.stored_hash[occ]
        self._shadow: Optional[_Shadow] = None
        # pinned trees (a cold tenant's range): their CSR rows are
        # referenced from host-evicted tables, so mutations are rejected
        # and compaction — which renumbers CSR rows — is disabled while
        # any tree is pinned
        self.pinned = np.zeros(bank.num_trees, dtype=bool)

    # ------------------------------------------------------------ plumbing
    def _tables(self):
        """The five (A, S) arena tables, in splice order."""
        b = self.bank
        return tuple(getattr(b, n) for n in _TABLES)

    @property
    def num_dead_rows(self) -> int:
        return int((~self.row_alive).sum())

    def _find_slots(self, trees: np.ndarray, hs_q: np.ndarray
                    ) -> Tuple[np.ndarray, np.ndarray]:
        """Exact-hash slot search (``FilterBank.find_exact``): maintenance
        matches on the stored 32-bit hash, not the 12-bit fingerprint, so
        it never mutates a colliding neighbour's slot."""
        return self.bank.find_exact(trees, hs_q)

    # ------------------------------------------------------------ queueing
    def _check_tree(self, tree: int) -> int:
        # reject at queue time: an out-of-range tree discovered mid-apply
        # would leave the CSR arena mutated but the placement crashed
        if not 0 <= tree < self.bank.num_trees:
            raise ValueError(f"tree {tree} out of range "
                             f"[0, {self.bank.num_trees})")
        if self.pinned[tree]:
            raise ValueError(f"tree {tree} is pinned (cold tenant): "
                             "reload the tenant before mutating it")
        return tree

    def pin_tree_range(self, lo: int, hi: int, pinned: bool = True) -> None:
        """Pin (or unpin) trees ``[lo, hi)`` — a cold tenant's range.
        Pinned trees reject queued mutations and keep compaction off
        bank-wide (their evicted slots reference live CSR row ids)."""
        self.pinned[lo:hi] = pinned

    def queue_insert(self, tree: int, key: Key, nodes: Sequence[int],
                     entity_id: int = NULL) -> None:
        """Record a (tree, entity) row for the next apply; ``nodes`` are
        the entity's node ids within that tree (its CSR row)."""
        self.delta.inserts.append((self._check_tree(int(tree)),
                                   _as_hash(key), int(entity_id),
                                   [int(n) for n in nodes]))

    def queue_delete(self, tree: int, key: Key) -> None:
        self.delta.deletes.append((self._check_tree(int(tree)),
                                   _as_hash(key)))

    # --------------------------------------------------------- direct ops
    def insert(self, tree: int, key: Key, nodes: Sequence[int],
               entity_id: int = NULL) -> None:
        """Queue + apply a single insert (bulk callers should queue)."""
        self.queue_insert(tree, key, nodes, entity_id)
        self.apply()

    def delete(self, tree: int, key: Key) -> bool:
        self.queue_delete(tree, key)
        before = self.stats["deleted"]
        self.apply()
        return self.stats["deleted"] > before

    # ------------------------------------------------------------- deletes
    def _clear_slots(self, rows: np.ndarray, slots: np.ndarray,
                     trees: np.ndarray) -> int:
        """Clear found slots + tombstone their CSR rows; returns count."""
        found = rows >= 0
        if not found.any():
            return 0
        fps, temps, heads, eids, hs = self._tables()
        r, s = rows[found], slots[found]
        rids = heads[r, s].astype(np.int64)
        fps[r, s] = hashing.EMPTY_FP
        temps[r, s] = 0
        heads[r, s] = NULL
        eids[r, s] = NULL
        hs[r, s] = 0
        self.row_alive[rids] = False
        b = self.bank
        b.num_items -= np.bincount(trees[found],
                                   minlength=b.num_trees).astype(np.int32)
        return int(found.sum())

    def _apply_deletes(self, trees: np.ndarray, hs_q: np.ndarray
                       ) -> Tuple[int, int]:
        rows, slots = self._find_slots(trees, hs_q)
        n = self._clear_slots(rows, slots, trees)
        return n, int(trees.shape[0]) - n

    # ------------------------------------------------------------- inserts
    def _append_rows(self, trees: np.ndarray, hs_q: np.ndarray,
                     eids: np.ndarray, nodes: List[List[int]]) -> np.ndarray:
        """Grow the CSR arena by one row per insert; returns new row ids."""
        b = self.bank
        k = trees.shape[0]
        lens = np.asarray([len(ns) for ns in nodes], dtype=np.int32)
        base = int(b.csr_offsets[-1])
        new_off = base + np.cumsum(lens, dtype=np.int32)
        b.csr_offsets = np.concatenate([b.csr_offsets, new_off])
        flat = (np.concatenate([np.asarray(ns, np.int32) for ns in nodes])
                if lens.sum() else np.zeros(0, np.int32))
        b.csr_nodes = np.concatenate([b.csr_nodes, flat])
        r0 = b.num_rows
        b.row_tree = np.concatenate([b.row_tree, trees.astype(np.int32)])
        b.row_entity = np.concatenate([b.row_entity, eids.astype(np.int32)])
        self.row_alive = np.concatenate([self.row_alive, np.ones(k, bool)])
        self.row_hash = np.concatenate([self.row_hash,
                                        hs_q.astype(np.uint32)])
        return np.arange(r0, r0 + k, dtype=np.int32)

    def _apply_inserts(self, trees: np.ndarray, hs_q: np.ndarray,
                       eids: np.ndarray, nodes: List[List[int]]
                       ) -> Tuple[int, int]:
        b = self.bank
        # replace-existing: a live (tree, hash) is deleted first so the
        # one-slot-per-key invariant (and churn equivalence) holds
        rows, slots = self._find_slots(trees, hs_q)
        replaced = self._clear_slots(rows, slots, trees)

        # per-tree pre-expansion so every receiving tree stays under the
        # load threshold — tree-local: only the overflowing trees restage
        adds = np.bincount(trees, minlength=b.num_trees)
        over = (b.num_items + adds) >= \
            self.load_threshold * b.tree_nb.astype(np.int64) * b.slots
        for t in np.flatnonzero(over):
            nb = int(b.tree_nb[t])
            target = int(b.num_items[t]) + int(adds[t])
            while target >= self.load_threshold * nb * b.slots:
                nb *= 2
            self._restage_tree(int(t), nb)
            self.stats["expansions"] += 1

        new_rows = self._append_rows(trees, hs_q, eids, nodes)
        fp = hashing.fingerprint(hs_q)
        mask = (b.tree_nb[trees] - 1).astype(np.uint32)
        i1 = hashing.bucket_i1_masked(hs_q, mask)
        i2 = hashing.alt_bucket_masked(i1, fp, mask)
        base = b.bucket_offsets[trees].astype(np.int64)
        arena_base, arena_mask = b.arena_base_mask()
        r_head, r_eid, r_hash, r_temp = bulk_place(
            *self._tables(), fp, base + i1.astype(np.int64),
            base + i2.astype(np.int64), new_rows, eids.astype(np.int32),
            hs_q, nb=0, rng=self._rng, row_base=arena_base,
            row_mask=arena_mask)
        b.num_items += np.bincount(trees,
                                   minlength=b.num_trees).astype(np.int32)
        # scalar eviction fallback; a dead kick chain restages ONLY the
        # failing tree's segment at double nb (the tree-local restage
        # re-homes every live row of that tree, including the still-
        # homeless remainder, so later remainder items of a restaged tree
        # are already placed and must be skipped)
        restaged = set()
        for j in range(r_head.size):
            rid = int(r_head[j])
            tree = int(b.row_tree[rid])
            if tree in restaged:
                continue
            lo, _ = b.segment(tree)
            if not _scalar_insert(
                    *self._tables(), lo, int(b.tree_nb[tree]),
                    b.slots, int(r_hash[j]), rid, int(r_eid[j]),
                    self._rng, self.max_kicks, temp=int(r_temp[j])):
                self._restage_tree(tree, 2 * int(b.tree_nb[tree]))
                self.stats["expansions"] += 1
                restaged.add(tree)
        return int(trees.shape[0]), replaced

    # ------------------------------------------------------------- apply
    @staticmethod
    def _dedupe_last(trees: np.ndarray, hs_q: np.ndarray) -> np.ndarray:
        """Indices keeping only the last occurrence of each (tree, hash)."""
        key = trees.astype(np.uint64) << np.uint64(32) | \
            hs_q.astype(np.uint64)
        _, idx = np.unique(key[::-1], return_index=True)
        return np.sort(key.shape[0] - 1 - idx)

    def apply(self) -> Dict[str, int]:
        """Apply the pending delta: deletes, then inserts (bulk_place with
        scalar fallback).  Returns per-call stats."""
        d, self.delta = self.delta, BankDelta()
        out = {"inserted": 0, "deleted": 0, "replaced": 0,
               "missed_deletes": 0}
        if d.deletes:
            trees = np.asarray([t for t, _ in d.deletes], np.int64)
            hs_q = np.asarray([h for _, h in d.deletes], np.uint32)
            keep = self._dedupe_last(trees, hs_q)
            n, miss = self._apply_deletes(trees[keep], hs_q[keep])
            out["deleted"] = n
            out["missed_deletes"] = miss
        if d.inserts:
            trees = np.asarray([t for t, _, _, _ in d.inserts], np.int64)
            hs_q = np.asarray([h for _, h, _, _ in d.inserts], np.uint32)
            eids = np.asarray([e for _, _, e, _ in d.inserts], np.int64)
            keep = self._dedupe_last(trees, hs_q)
            nodes = [d.inserts[int(i)][3] for i in keep]
            n, rep = self._apply_inserts(trees[keep], hs_q[keep],
                                         eids[keep], nodes)
            out["inserted"] = n
            out["replaced"] = rep
        for k, v in out.items():
            self.stats[k] += v
        return out

    # --------------------------------------------------- expand / compact
    def _restage_tree(self, tree: int, new_nb: int) -> None:
        """Tree-local restage: re-place only ``tree``'s live rows into a
        fresh ``(new_nb, S)`` segment and splice it into the arena.

        Everything outside the segment is untouched byte-for-byte — only
        ``bucket_offsets`` after the tree shift by the size delta.  CSR
        rows are *not* renumbered (no compaction), so previously returned
        row ids and every other tree's head payloads stay valid.  Slot
        temperatures are preserved; rows that are alive but currently
        homeless (a mid-insert remainder) are placed too.
        """
        if self.pinned[tree]:
            raise RuntimeError(f"restage of pinned tree {tree} (cold "
                               "tenant): reload the tenant first")
        b = self.bank
        lo, hi = b.segment(tree)
        s = b.slots
        temp_r = np.zeros(max(b.num_rows, 1), np.int32)
        occ = b.fingerprints[lo:hi] != hashing.EMPTY_FP
        temp_r[b.heads[lo:hi][occ]] = b.temperature[lo:hi][occ]
        rows = np.flatnonzero(self.row_alive
                              & (b.row_tree == tree)).astype(np.int64)
        hs_q = self.row_hash[rows]
        eids = b.row_entity[rows].astype(np.int32)
        nb = int(new_nb)
        while True:
            self._seed += 1
            rng = np.random.default_rng(self._seed)
            seg = (np.full((nb, s), hashing.EMPTY_FP, np.uint32),
                   np.zeros((nb, s), np.int32),
                   np.full((nb, s), NULL, np.int32),
                   np.full((nb, s), NULL, np.int32),
                   np.zeros((nb, s), np.uint32))
            fp = hashing.fingerprint(hs_q)
            i1 = hashing.bucket_i1(hs_q, nb)
            i2 = hashing.alt_bucket(i1, fp, nb)
            r_head, r_eid, r_hash, r_temp = bulk_place(
                *seg, fp, i1.astype(np.int64), i2.astype(np.int64),
                rows.astype(np.int32), eids, hs_q, nb=nb, rng=rng,
                new_temps=temp_r[rows])
            ok = True
            for j in range(r_head.size):
                if not _scalar_insert(*seg, 0, nb, s, int(r_hash[j]),
                                      int(r_head[j]), int(r_eid[j]), rng,
                                      self.max_kicks, temp=int(r_temp[j])):
                    ok = False
                    break
            if ok and rows.size < self.load_threshold * nb * s:
                break
            nb *= 2
        for name, new_seg in zip(_TABLES, seg):
            old = getattr(b, name)
            setattr(b, name, np.concatenate([old[:lo], new_seg, old[hi:]]))
        delta = nb - int(b.tree_nb[tree])
        b.tree_nb[tree] = nb
        b.bucket_offsets[tree + 1:] += delta
        b.num_items[tree] = rows.size

    def _rebuild(self, tree_nb: np.ndarray) -> None:
        """Restage the whole bank at the given per-tree bucket counts:
        compact the CSR arena to live rows, re-place every live row
        (temperatures preserved), and adopt the new tables into the
        existing bank object so external references stay valid."""
        if self.pinned.any():
            # a cold tenant's evicted tables reference CSR rows by id and
            # its rows are still marked alive here — a rebuild would both
            # renumber the former and resurrect the latter
            raise RuntimeError("bank rebuild while trees are pinned "
                               "(cold tenant): reload tenants first")
        b = self.bank
        occ = b.fingerprints != hashing.EMPTY_FP
        temp_r = np.zeros(max(b.num_rows, 1), np.int32)
        temp_r[b.heads[occ]] = b.temperature[occ]

        live = np.flatnonzero(self.row_alive)
        starts = b.csr_offsets[live].astype(np.int64)
        lens = (b.csr_offsets[live + 1].astype(np.int64) - starts)
        new_off = np.zeros(live.size + 1, dtype=np.int32)
        np.cumsum(lens, out=new_off[1:])
        total = int(lens.sum())
        pos = np.arange(total, dtype=np.int64)
        idx = pos + np.repeat(starts - new_off[:-1], lens)
        new_nodes = (b.csr_nodes[idx] if total else np.zeros(0, np.int32))

        self._seed += 1
        fresh = build_bank_from_rows(
            b.num_trees, b.row_tree[live], b.row_entity[live],
            self.row_hash[live], new_off, new_nodes,
            num_buckets=np.asarray(tree_nb, np.int64), slots=b.slots,
            seed=self._seed, max_kicks=self.max_kicks,
            row_temp=temp_r[live])
        for f in dataclasses.fields(FilterBank):
            setattr(b, f.name, getattr(fresh, f.name))
        self.row_hash = self.row_hash[live].copy()
        self.row_alive = np.ones(live.size, dtype=bool)

    def expand(self) -> None:
        """Bank-wide restage with every tree at double nb (temperatures
        preserved).  Rarely what you want with the ragged arena — prefer
        :meth:`expand_tree`, which grows only the hot tree."""
        self._rebuild(self.bank.tree_nb.astype(np.int64) * 2)
        self.stats["expansions"] += 1

    def expand_tree(self, tree: int, force: bool = False) -> bool:
        """Single-tree expansion: restage only ``tree``'s arena segment at
        double ``nb_t``.  Every other segment stays byte-identical and CSR
        rows keep their ids — O(hot tree), not O(bank).  No-op unless that
        tree is actually past the load threshold, or ``force``.

        Direct calls change the arena geometry, so any device state staged
        from this bank must be restaged before its temperature is absorbed
        (a stale absorb raises loudly).  Overflow expansion inside
        ``maintain()`` needs no care: it runs after the absorb, and the
        caller restages on ``report.changed``."""
        b = self.bank
        load = float(b.num_items[tree]) / (int(b.tree_nb[tree]) * b.slots)
        if not force and load < self.load_threshold:
            return False
        self._restage_tree(int(tree), 2 * int(b.tree_nb[tree]))
        self.stats["expansions"] += 1
        return True

    def shrink_tree(self, tree: int, force: bool = False) -> bool:
        """Single-tree arena shrink — ``expand_tree`` in reverse.

        Restages only ``tree``'s segment at the smallest power-of-two nb
        that keeps it under ``DEFAULT_LOAD_TARGET`` (an empty tree drops to
        ``EMPTY_TREE_NB``), through the same splice machinery: every other
        segment stays byte-identical, CSR rows keep their ids,
        temperatures are preserved.  Without ``force`` it only fires when
        the tree's load factor sits below ``shrink_load`` (hysteresis: a
        briefly cold tree should not flap between sizes)."""
        b = self.bank
        nb = int(b.tree_nb[tree])
        items = int(b.num_items[tree])
        target = int(_pick_tree_buckets(np.asarray([items]), b.slots,
                                        DEFAULT_LOAD_TARGET)[0])
        if target >= nb:
            return False                       # nothing to reclaim
        if not force:
            if self.shrink_load is None:
                return False
            if items / (nb * b.slots) >= self.shrink_load:
                return False
        self._restage_tree(int(tree), target)
        self.stats["shrinks"] += 1
        return True

    def maybe_shrink(self) -> int:
        """Shrink the coldest overprovisioned tree, at most one per idle
        window — a single-segment splice keeps the restage incremental
        (``plan_restage`` stays off the full-restage path)."""
        if self.shrink_load is None:
            return 0
        for t in np.argsort(self.bank.load_factors):
            if self.shrink_tree(int(t)):
                return 1
        return 0

    def packing_stats(self) -> Dict[str, object]:
        """Per-tree load / overprovision report for the shrink policy:
        ``ideal_nb`` is what a fresh build would allocate each tree today,
        ``overprovision`` the ratio of live arena rows to that ideal,
        ``est_fpr`` the per-tree empirical false-positive-rate estimate
        (:func:`repro.core.bank.estimate_fpr` from load and fingerprint
        bits).  Every value is pure Python (``json.dumps``-ready) — this
        dict rides verbatim in observability snapshots."""
        b = self.bank
        ideal = _pick_tree_buckets(b.num_items, b.slots,
                                   DEFAULT_LOAD_TARGET)
        ideal_rows = int(ideal.sum())
        load = b.load_factors
        return dict(load=[float(x) for x in load],
                    tree_nb=[int(x) for x in b.tree_nb],
                    ideal_nb=[int(x) for x in ideal],
                    est_fpr=[float(x)
                             for x in estimate_fpr(load, b.slots)],
                    arena_rows=int(b.total_buckets),
                    ideal_rows=ideal_rows,
                    overprovision=float(b.total_buckets
                                        / max(1, ideal_rows)),
                    dead_rows=int(self.num_dead_rows))

    def compact(self) -> bool:
        """Reclaim tombstoned CSR rows (per-tree nb preserved); returns
        True if ran."""
        if self.num_dead_rows == 0:
            return False
        self._rebuild(self.bank.tree_nb.astype(np.int64).copy())
        self.stats["compactions"] += 1
        return True

    def maybe_compact(self) -> bool:
        if self.pinned.any():
            return False               # cold tenants pin CSR numbering
        dead = self.num_dead_rows
        total = max(1, self.bank.num_rows)
        if dead >= self.compact_min_dead and \
                dead / total >= self.compact_dead_frac:
            return self.compact()
        return False

    # --------------------------------------------- temperature feedback
    def absorb(self, device_state) -> int:
        """Harvest device temperature into the host bank; accumulate the
        bump count the sort trigger integrates.  The restage shadow tracks
        the absorbed values too: after a successful absorb the device
        already holds these temperatures, so they are never re-staged."""
        bumps = self.bank.absorb_temperature(device_state)
        if self._shadow is not None and \
                self._shadow.temperature.shape == self.bank.temperature.shape:
            self._shadow.temperature[...] = self.bank.temperature
        self.bumps_since_sort += bumps
        self.stats["absorbed_bumps"] += bumps
        return bumps

    def sort(self) -> None:
        """Host-side bank-wide idle sort (hot fingerprints to slot 0)."""
        self.bank.sort_buckets()
        self.bumps_since_sort = 0
        self.stats["sorts"] += 1

    def maybe_sort(self) -> bool:
        if self.bumps_since_sort >= self.sort_threshold:
            self.sort()
            return True
        return False

    # ------------------------------------------------------ idle-time hook
    def maintain(self, device_state=None) -> MaintenanceReport:
        """One idle-window pass: absorb device temperature (must run before
        any slot moves so layouts agree), apply the pending delta, compact
        if enough rows died, shrink a cold tree, sort if enough heat
        accumulated.  The caller restages its device state iff
        ``report.changed`` — synchronously, or through
        :meth:`plan_restage` + :func:`commit_restage`."""
        rep = MaintenanceReport()
        if device_state is not None:
            rep.absorbed_bumps = self.absorb(device_state)
        exp0 = self.stats["expansions"]
        if self.delta:
            out = self.apply()
            rep.inserted = out["inserted"]
            rep.deleted = out["deleted"]
            rep.replaced = out["replaced"]
            rep.missed_deletes = out["missed_deletes"]
        rep.compacted = self.maybe_compact()
        rep.expansions = self.stats["expansions"] - exp0
        # auto-shrink only in cycles that did not already resize a tree:
        # a second resized segment (or a compaction) would push the
        # restage plan onto the full path — the shrink waits a window
        if not rep.expansions and not rep.compacted:
            rep.shrinks = self.maybe_shrink()
        rep.sorted = self.maybe_sort()
        return rep

    # ------------------------------------------- double-buffered restage
    def mark_staged(self) -> None:
        """Record the bank's current content as what lives on device —
        call whenever a device state is (re)staged from this bank.  The
        next :meth:`plan_restage` diffs against this shadow."""
        b = self.bank
        self._shadow = _Shadow(
            fingerprints=b.fingerprints.copy(),
            temperature=b.temperature.copy(),
            heads=b.heads.copy(),
            tree_nb=b.tree_nb.copy(),
            bucket_offsets=b.bucket_offsets.copy(),
            num_rows=b.num_rows,
            compactions=self.stats["compactions"])

    def invalidate_shadow(self) -> None:
        """Drop the restage shadow — the next :meth:`plan_restage`
        classifies as ``full``, restaging the device state from the bank
        from scratch.  The maintenance fault domain calls this after a
        failed prepare/commit: the bank may have advanced past what the
        device serves, and a full restage from the (always-consistent)
        host bank is the recovery path that needs no diff bookkeeping."""
        self._shadow = None

    def _diff_region(self, lo_new: int, hi_new: int,
                     lo_old: int) -> np.ndarray:
        """Arena rows in [lo_new, hi_new) whose staged-table content
        differs from the shadow region of the same length at lo_old
        (new-coordinate indices)."""
        sh, b = self._shadow, self.bank
        n = hi_new - lo_new
        if n <= 0:
            return np.zeros(0, np.int64)
        d = (b.fingerprints[lo_new:hi_new]
             != sh.fingerprints[lo_old:lo_old + n]).any(axis=1)
        d |= (b.temperature[lo_new:hi_new]
              != sh.temperature[lo_old:lo_old + n]).any(axis=1)
        d |= (b.heads[lo_new:hi_new]
              != sh.heads[lo_old:lo_old + n]).any(axis=1)
        return np.flatnonzero(d) + lo_new

    def _classify(self) -> _HostPlan:
        """Diff the bank against the shadow and classify the cheapest
        restage that reproduces it; re-marks the shadow (the caller is
        expected to commit the plan before mutating the bank again)."""
        b, sh = self.bank, self._shadow
        try:
            if sh is None or self.stats["compactions"] != sh.compactions \
                    or b.num_rows < sh.num_rows:
                return _HostPlan(kind="full")
            plan = _HostPlan(kind="delta",
                             csr_appended=b.num_rows > sh.num_rows)
            changed = np.flatnonzero(b.tree_nb != sh.tree_nb)
            if changed.size > 1:
                return _HostPlan(kind="full")
            if changed.size == 1:
                t = int(changed[0])
                lo = int(sh.bucket_offsets[t])
                hi_old = int(sh.bucket_offsets[t + 1])
                hi_new = int(b.bucket_offsets[t + 1])
                plan.kind = "segment"
                plan.seg = (t, lo, hi_old, hi_new)
                r1 = self._diff_region(0, lo, 0)
                r2 = self._diff_region(hi_new, b.total_buckets, hi_old)
                plan.rows = np.concatenate([r1, r2])
                # commit-time temperature merge guard: staged fp == what
                # is live on device right now (= the shadow; r2 rows sit
                # past the resized segment, shifted in old coordinates)
                plan.keep = np.concatenate([
                    b.fingerprints[r1] == sh.fingerprints[r1],
                    b.fingerprints[r2]
                    == sh.fingerprints[r2 - (hi_new - hi_old)]])
            else:
                plan.rows = self._diff_region(0, b.total_buckets, 0)
                plan.keep = (b.fingerprints[plan.rows]
                             == sh.fingerprints[plan.rows])
                if plan.rows.size == 0 and not plan.csr_appended:
                    plan.kind = "none"
            return plan
        finally:
            self.mark_staged()

    def plan_restage(self) -> PendingRestage:
        """Diff against the shadow and stage only the changed bytes for
        :func:`commit_restage` — host planning plus async payload
        dispatch, safe to run while the pre-plan device state keeps
        serving.  The bank must not mutate again before commit."""
        import jax.numpy as jnp
        host = self._classify()
        get_registry().counter(
            "maint.plans", "restage plans by kind").inc(kind=host.kind)
        if host.kind in ("none", "full"):
            return PendingRestage(kind=host.kind)
        b = self.bank
        plan = PendingRestage(kind=host.kind)
        rows = host.rows
        if rows is not None and rows.size:
            k = rows.size
            kp = -(-k // _SCATTER_PAD) * _SCATTER_PAD
            # sentinel = arena rows: out of bounds, dropped by the scatter
            idx = np.full(kp, b.total_buckets, np.int32)
            idx[:k] = rows
            pad = np.zeros((kp - k, b.slots), np.int32)
            plan.rows = jnp.asarray(idx)
            plan.val_fps = jnp.asarray(np.concatenate(
                [b.fingerprints[rows], pad.astype(np.uint32)]))
            plan.val_temp = jnp.asarray(np.concatenate(
                [b.temperature[rows], pad]))
            plan.val_heads = jnp.asarray(np.concatenate(
                [b.heads[rows], pad]))
            plan.val_keep = jnp.asarray(np.concatenate(
                [host.keep, np.zeros((kp - k, b.slots), bool)]))
            plan.changed_rows = k
        if host.seg is not None:
            t, lo, hi_old, hi_new = host.seg
            plan.seg_tree, plan.seg_lo, plan.seg_hi_old = t, lo, hi_old
            plan.seg_fps = jnp.asarray(b.fingerprints[lo:hi_new])
            plan.seg_temp = jnp.asarray(b.temperature[lo:hi_new])
            plan.seg_heads = jnp.asarray(b.heads[lo:hi_new])
            plan.tree_nb = b.tree_nb.copy()
            plan.bucket_offsets = b.bucket_offsets.copy()
            plan.changed_rows += hi_new - lo
        if host.csr_appended:
            # the CSR arena is replicated and O(rows) — staging it whole
            # at plan time (async device_put, off the commit path) beats
            # an on-device append that recompiles per grown shape; pad_csr
            # matches from_bank so the committed shapes stay stable
            off, nodes = pad_csr(b.csr_offsets, b.csr_nodes)
            plan.csr_offsets = jnp.asarray(off)
            plan.csr_nodes = jnp.asarray(nodes)
        return plan


class ShardedMaintenanceEngine:
    """Shard-local maintenance over a :class:`ShardedBank`.

    One :class:`MaintenanceEngine` per shard, each owning only its shard's
    sub-bank: global-tree operations route to the owning shard's engine
    (``tree_starts`` range search), so an insert, delete, compaction or
    *expansion* mutates exactly one shard's tables.  With the ragged arena
    an expansion is narrower still: only the hot tree's segment within the
    owning shard restages — every other tree's segment (same shard or not)
    stays byte-identical, and a restage after maintenance ships only
    changed blocks' worth of new content.

    Temperature harvesting slices the packed ``(D*Apad, S)`` device arena
    into per-shard owner blocks first (``ShardedBank.temperature_blocks``),
    so each slot's bumps are counted once against the owning shard's own
    baseline — the padding rows of the packed layout never enter the delta.
    """

    def __init__(self, sbank: ShardedBank, seed: int = 0x5EED, **policy):
        self.sbank = sbank
        # distinct per-shard seeds: shard-local kick chains must not be
        # correlated replicas of each other
        self.engines = [MaintenanceEngine(b, seed=seed + 101 * d, **policy)
                        for d, b in enumerate(sbank.banks)]

    # ------------------------------------------------------------ routing
    def _owner(self, tree: int) -> Tuple[int, int]:
        return self.sbank.owner(int(tree))

    def queue_insert(self, tree: int, key: Key, nodes: Sequence[int],
                     entity_id: int = NULL) -> None:
        d, lt = self._owner(tree)
        self.engines[d].queue_insert(lt, key, nodes, entity_id)

    def queue_delete(self, tree: int, key: Key) -> None:
        d, lt = self._owner(tree)
        self.engines[d].queue_delete(lt, key)

    def pin_tree_range(self, lo: int, hi: int, pinned: bool = True) -> None:
        """Pin (or unpin) global trees ``[lo, hi)`` in their owning
        shards' engines (see :meth:`MaintenanceEngine.pin_tree_range`)."""
        starts = self.sbank.tree_starts
        for d, e in enumerate(self.engines):
            a = max(lo, int(starts[d])) - int(starts[d])
            z = min(hi, int(starts[d + 1])) - int(starts[d])
            if a < z:
                e.pin_tree_range(a, z, pinned)

    def insert(self, tree: int, key: Key, nodes: Sequence[int],
               entity_id: int = NULL) -> None:
        d, lt = self._owner(tree)
        self.engines[d].insert(lt, key, nodes, entity_id)

    def delete(self, tree: int, key: Key) -> bool:
        d, lt = self._owner(tree)
        return self.engines[d].delete(lt, key)

    def apply(self) -> Dict[str, int]:
        out = {"inserted": 0, "deleted": 0, "replaced": 0,
               "missed_deletes": 0}
        for e in self.engines:
            if e.delta:
                for k, v in e.apply().items():
                    out[k] += v
        return out

    # --------------------------------------------------- expand / compact
    def expand_tree(self, tree: int, force: bool = False) -> bool:
        """Tree-local expansion: restages only the hot tree's arena
        segment within its owning shard — the other trees' segments (and
        every other shard) are untouched."""
        d, lt = self._owner(tree)
        return self.engines[d].expand_tree(lt, force=force)

    def shrink_tree(self, tree: int, force: bool = False) -> bool:
        """Tree-local shrink within the owning shard (``expand_tree`` in
        reverse — every other segment and shard byte-identical)."""
        d, lt = self._owner(tree)
        return self.engines[d].shrink_tree(lt, force=force)

    def maybe_shrink(self) -> int:
        return sum(e.maybe_shrink() for e in self.engines)

    def packing_stats(self) -> Dict[str, object]:
        """Global packing report: per-tree lists concatenate in global
        tree order; scalars aggregate across shards.  Pure Python, like
        the per-shard reports it merges."""
        per = [e.packing_stats() for e in self.engines]
        arena = sum(p["arena_rows"] for p in per)
        ideal = sum(p["ideal_rows"] for p in per)
        cat = lambda k: [x for p in per for x in p[k]]       # noqa: E731
        return dict(
            load=cat("load"), tree_nb=cat("tree_nb"),
            ideal_nb=cat("ideal_nb"), est_fpr=cat("est_fpr"),
            arena_rows=int(arena), ideal_rows=int(ideal),
            overprovision=float(arena / max(1, ideal)),
            dead_rows=int(sum(p["dead_rows"] for p in per)))

    def maybe_compact(self) -> bool:
        return any([e.maybe_compact() for e in self.engines])

    # --------------------------------------------- temperature feedback
    def absorb(self, device_state) -> int:
        blocks = self.sbank.temperature_blocks(device_state)
        return sum(e.absorb(blk)
                   for e, blk in zip(self.engines, blocks))

    def maybe_sort(self) -> bool:
        return any([e.maybe_sort() for e in self.engines])

    # ------------------------------------------------------ idle-time hook
    def maintain(self, device_state=None) -> MaintenanceReport:
        """One idle-window pass over every shard (absorb -> delta ->
        compact -> sort, shard by shard).  The packed temperature is sliced
        against the *pre-mutation* geometry up front, so an expansion on an
        earlier shard cannot shift a later shard's harvest window."""
        blocks = (self.sbank.temperature_blocks(device_state)
                  if device_state is not None
                  else [None] * self.sbank.num_shards)
        rep = MaintenanceReport()
        for e, blk in zip(self.engines, blocks):
            r = e.maintain(blk)
            rep.absorbed_bumps += r.absorbed_bumps
            rep.inserted += r.inserted
            rep.deleted += r.deleted
            rep.replaced += r.replaced
            rep.missed_deletes += r.missed_deletes
            rep.expansions += r.expansions
            rep.shrinks += r.shrinks
            rep.compacted = rep.compacted or r.compacted
            rep.sorted = rep.sorted or r.sorted
        return rep

    # ------------------------------------------- double-buffered restage
    def mark_staged(self) -> None:
        """Record every shard's current content as staged — call whenever
        a packed device state is built from this sharded bank."""
        for e in self.engines:
            e.mark_staged()

    def invalidate_shadow(self) -> None:
        """Drop every shard's restage shadow — the next plan is ``full``
        (see :meth:`MaintenanceEngine.invalidate_shadow`)."""
        for e in self.engines:
            e.invalidate_shadow()

    def plan_restage(self) -> PendingShardedRestage:
        """Classify every shard's diff and stage a packed splice plan.

        Only shards whose sub-bank actually mutated contribute payload
        rows — a non-owner shard's block is never written (its scatter
        lane is all-sentinel and its head shift zero), so its packed
        arena bytes stay identical through commit.  An insert into shard
        d renumbers merged CSR rows of shards > d; that is expressed as
        the per-shard ``head_shift`` (an in-place elementwise add on
        device — no host bytes) plus a wholesale restage of the
        replicated merged CSR.
        """
        import jax.numpy as jnp
        sb = self.sbank
        d = sb.num_shards
        old_rows = [(e._shadow.num_rows if e._shadow is not None else -1)
                    for e in self.engines]
        old_arena = [(int(e._shadow.bucket_offsets[-1])
                      if e._shadow is not None else -1)
                     for e in self.engines]
        host = [e._classify() for e in self.engines]   # re-marks shadows
        plans = get_registry().counter("maint.plans",
                                       "restage plans by kind")
        if any(p.kind == "full" for p in host):
            plans.inc(kind="full")
            return PendingShardedRestage(kind="full")
        if all(p.kind == "none" for p in host):
            plans.inc(kind="none")
            return PendingShardedRestage(kind="none")
        plans.inc(kind="splice")
        base_new = sb.shard_row_base()
        base_old = np.zeros(d + 1, np.int64)
        np.cumsum(old_rows, out=base_old[1:])
        shift = (base_new[:d] - base_old[:d]).astype(np.int32)

        plan = PendingShardedRestage(kind="splice")
        kmax = max(p.rows.size if p.rows is not None else 0 for p in host)
        kp = -(-max(kmax, 1) // _SCATTER_PAD) * _SCATTER_PAD
        sentinel = 2 ** 30                 # past any block: always dropped
        rows = np.full((d, kp), sentinel, np.int32)
        s = sb.slots
        vf = np.zeros((d, kp, s), np.uint32)
        vt = np.zeros((d, kp, s), np.int32)
        vh = np.full((d, kp, s), NULL, np.int32)
        vk = np.zeros((d, kp, s), bool)
        any_rows = False
        for k, (p, b) in enumerate(zip(host, sb.banks)):
            r = p.rows if p.rows is not None else np.zeros(0, np.int64)
            if r.size:
                any_rows = True
                rows[k, :r.size] = r
                vf[k, :r.size] = b.fingerprints[r]
                vt[k, :r.size] = b.temperature[r]
                vk[k, :r.size] = p.keep
                heads = b.heads[r]
                vh[k, :r.size] = np.where(heads != NULL,
                                          heads + np.int32(base_new[k]),
                                          NULL)
            plan.changed_rows += int(r.size)
            if p.seg is not None:
                _, lo, _, _ = p.seg
                # the splice payload spans [lo, A_d_new) — the resized
                # segment plus the shifted later trees — extended with
                # empty rows up to the old A_d so a shrink clears its tail
                a_new = b.total_buckets
                end = max(a_new, old_arena[k])
                segf = np.full((end - lo, s), hashing.EMPTY_FP, np.uint32)
                segt = np.zeros((end - lo, s), np.int32)
                segh = np.full((end - lo, s), NULL, np.int32)
                segf[:a_new - lo] = b.fingerprints[lo:]
                segt[:a_new - lo] = b.temperature[lo:]
                hh = b.heads[lo:]
                segh[:a_new - lo] = np.where(hh != NULL,
                                             hh + np.int32(base_new[k]),
                                             NULL)
                plan.segments.append((k, lo, jnp.asarray(segf),
                                      jnp.asarray(segt), jnp.asarray(segh)))
                plan.changed_rows += end - lo
        if any_rows or np.any(shift != 0):
            # one fused op applies the head shift + the row scatter; a
            # shard with nothing to do gets a zero shift and all-sentinel
            # rows — its block bytes come out identical
            plan.rows = jnp.asarray(rows)
            plan.val_fps = jnp.asarray(vf)
            plan.val_temp = jnp.asarray(vt)
            plan.val_heads = jnp.asarray(vh)
            plan.val_keep = jnp.asarray(vk)
            plan.head_shift = jnp.asarray(shift)
        plan.new_arena_rows = [b.total_buckets for b in sb.banks]
        if plan.segments:
            plan.tree_offset = sb.tree_arena_offsets().astype(np.int32)
            plan.tree_nb = sb.tree_nb_map()
        if any(p.csr_appended for p in host):
            plan.csr_offsets, plan.csr_nodes = pad_csr(*sb.merged_csr())
        return plan

    # ------------------------------------------------------------- stats
    @property
    def stats(self) -> Dict[str, int]:
        out: Dict[str, int] = {}
        for e in self.engines:
            for k, v in e.stats.items():
                out[k] = out.get(k, 0) + v
        return out

    @property
    def bumps_since_sort(self) -> int:
        return sum(e.bumps_since_sort for e in self.engines)

    @property
    def num_dead_rows(self) -> int:
        return sum(e.num_dead_rows for e in self.engines)


# --------------------------------------------------------------- commit

def _commit_replicated(state, plan: PendingRestage, bank: FilterBank,
                       forest):
    import jax.numpy as jnp

    from .bank import splice_arena_rows, splice_arena_segment
    from .trag import CFTDeviceState
    if plan.kind == "none":
        return state
    if plan.kind == "full":
        return CFTDeviceState.from_bank(bank, forest)
    fps, temp, heads = state.fingerprints, state.temperature, state.heads
    kw = {}
    if plan.kind == "segment":
        fps, temp, heads = splice_arena_segment(
            fps, temp, heads, plan.seg_fps, plan.seg_temp, plan.seg_heads,
            lo=plan.seg_lo, hi=plan.seg_hi_old)
        kw["bucket_offsets"] = jnp.asarray(
            plan.bucket_offsets.astype(np.int32))
        kw["tree_nb"] = jnp.asarray(plan.tree_nb.astype(np.int32))
    if plan.rows is not None:
        fps, temp, heads = splice_arena_rows(
            fps, temp, heads, plan.rows, plan.val_fps, plan.val_temp,
            plan.val_heads, plan.val_keep)
    kw.update(fingerprints=fps, temperature=temp, heads=heads)
    if plan.csr_offsets is not None:
        kw["csr_offsets"] = plan.csr_offsets
        kw["csr_nodes"] = plan.csr_nodes
    return dataclasses.replace(state, **kw)


def _commit_sharded(state, plan: PendingShardedRestage, sbank: ShardedBank,
                    forest):
    import jax.numpy as jnp

    from .distributed import (sharded_apply_delta, sharded_splice_segment,
                              stage_sharded_bank)
    if plan.kind == "none":
        return state
    apad = state.arena_rows_per_shard
    if plan.kind == "full" or (plan.new_arena_rows is not None
                               and max(plan.new_arena_rows) > apad):
        # a segment outgrew the packed padding — only a repack can grow
        # every shard's block, so fall back to the from-scratch stage
        return stage_sharded_bank(sbank, forest, state.mesh, state.axis)
    fps, temp, heads = state.fingerprints, state.temperature, state.heads
    if plan.rows is not None:
        fps, temp, heads = sharded_apply_delta(
            fps, temp, heads, plan.rows, plan.val_fps, plan.val_temp,
            plan.val_heads, plan.val_keep, plan.head_shift,
            state.mesh, state.axis)
    for owner, start, sf, st, sh in plan.segments:
        fps, temp, heads = sharded_splice_segment(
            fps, temp, heads, sf, st, sh,
            jnp.int32(owner), jnp.int32(start), state.mesh, state.axis)
    kw = dict(fingerprints=fps, temperature=temp, heads=heads)
    _place_sharded_replicated(state, plan)   # no-op if warm already did
    if plan.tree_offset is not None:
        kw["tree_offset"] = plan.tree_offset
        kw["tree_nb"] = plan.tree_nb
    if plan.csr_offsets is not None:
        kw["csr_offsets"] = plan.csr_offsets
        kw["csr_nodes"] = plan.csr_nodes
    return dataclasses.replace(state, **kw)


def _place_sharded_replicated(state, plan: PendingShardedRestage) -> None:
    """Stage the plan's replicated tables (merged CSR, per-tree routing)
    onto the mesh in place — idempotent, so ``warm_restage`` runs it in
    the prepare phase and commit finds them already resident."""
    import jax
    import jax.numpy as jnp
    from jax.sharding import NamedSharding, PartitionSpec as P
    first = (plan.csr_offsets if plan.csr_offsets is not None
             else plan.tree_offset)
    if first is None or isinstance(first, jax.Array):
        return
    rep = NamedSharding(state.mesh, P())
    put_r = lambda a: jax.device_put(jnp.asarray(a), rep)    # noqa: E731
    if plan.tree_offset is not None:
        plan.tree_offset = put_r(plan.tree_offset)
        plan.tree_nb = put_r(plan.tree_nb)
    if plan.csr_offsets is not None:
        plan.csr_offsets = put_r(plan.csr_offsets)
        plan.csr_nodes = put_r(plan.csr_nodes if plan.csr_nodes.size
                               else np.zeros(1, np.int32))


def commit_restage(state, plan, engine, forest):
    """Apply a staged restage plan to the live device state — the
    O(changed-bytes) second phase of the double-buffered restage.

    ``state`` is the ``CFTDeviceState`` / ``ShardedBankState`` the plan
    was computed against (plus any temperature bumps it accumulated since
    — those **max-merge** into staged rows wherever the staged
    fingerprint matches the live one, so serving through the prepare
    window never silently drops heat; a slot whose key the plan moved or
    cleared takes the staged value); ``engine`` the maintenance engine
    that produced the plan.  Returns the post-commit state; the splice ops donate the old
    state's arena buffers, so the caller must drop every reference to
    ``state`` and use the returned value (on backends without donation
    support this degrades to a copy, never to corruption).
    """
    reg = get_registry()
    reg.counter("maint.commits", "restage commits by kind").inc(
        kind=plan.kind)
    reg.counter("maint.commit_rows",
                "arena rows spliced across commits").inc(plan.changed_rows)
    if isinstance(plan, PendingShardedRestage):
        return _commit_sharded(state, plan, engine.sbank, forest)
    return _commit_replicated(state, plan, engine.bank, forest)


def warm_restage(state, plan) -> None:
    """Pre-compile the commit's splice executables during the prepare
    phase, so :func:`commit_restage` pays pure execution.

    A segment splice changes the arena shape, so its executable cannot
    have been cached by earlier cycles; compiling it lazily at commit
    would put tens of milliseconds of XLA work on the serve-critical
    path — exactly the pause this machinery exists to remove.  Runs the
    commit computation on ``zeros_like`` dummies of the live state's
    arrays (the plan's payloads are read-only and reused), populating the
    jit caches the real commit hits.  No-op for ``none``/``full`` plans
    (a full restage is staging work, not compilation).
    """
    import jax.numpy as jnp

    from .bank import splice_arena_rows, splice_arena_segment
    from .distributed import sharded_apply_delta, sharded_splice_segment
    z = lambda a: jnp.zeros_like(a)                       # noqa: E731
    if isinstance(plan, PendingShardedRestage):
        if plan.kind != "splice":
            return
        if plan.new_arena_rows is not None and \
                max(plan.new_arena_rows) > state.arena_rows_per_shard:
            return                                  # commit will repack
        _place_sharded_replicated(state, plan)   # CSR/routing staging off
        f, t, h = z(state.fingerprints), z(state.temperature), \
            z(state.heads)                       # the commit path too
        if plan.rows is not None:
            f, t, h = sharded_apply_delta(
                f, t, h, plan.rows, plan.val_fps, plan.val_temp,
                plan.val_heads, plan.val_keep, plan.head_shift,
                state.mesh, state.axis)
        for owner, start, sf, st, sh in plan.segments:
            f, t, h = sharded_splice_segment(
                f, t, h, sf, st, sh, jnp.int32(owner), jnp.int32(start),
                state.mesh, state.axis)
        return
    if plan.kind not in ("delta", "segment"):
        return
    f, t, h = z(state.fingerprints), z(state.temperature), z(state.heads)
    if plan.kind == "segment":
        f, t, h = splice_arena_segment(
            f, t, h, plan.seg_fps, plan.seg_temp, plan.seg_heads,
            lo=plan.seg_lo, hi=plan.seg_hi_old)
    if plan.rows is not None:
        splice_arena_rows(f, t, h, plan.rows, plan.val_fps, plan.val_temp,
                          plan.val_heads, plan.val_keep)


class RestageCoordinator:
    """The serving-side two-phase restage lifecycle, shared by
    ``ServeEngine``, ``RAGPipeline`` and ``AsyncServeEngine`` so its
    invariants live once:

    * plans never stack — a caller must commit (or drop) the pending plan
      before preparing another;
    * temperature harvesting must defer while a plan is pending
      (``deferring``) — the bank may already carry the next geometry, and
      bumps absorbed mid-flight would desync the staged payload;
    * the splice executables compile during prepare (``warm_restage``),
      never on the commit path.

    The three phases are serialized by one lock so ``prepare`` may run on
    a background maintenance thread strictly under in-flight batches
    while the serve thread keeps harvesting and committing: ``absorb``
    and non-blocking ``commit`` *try* the lock and fall back to a no-op
    rather than stall serving behind a host maintenance pass — skipped
    bumps stay on device (the commit max-merges them, the first
    post-commit absorb harvests them), a skipped commit retries at the
    next batch boundary.

    The caller owns the device state: ``prepare(state)`` runs the host
    maintenance pass and stages the plan; ``commit(state)`` returns the
    post-splice state (the old one is donated — drop it).
    """

    def __init__(self, engine, forest, breaker: Optional[
            "MaintenanceBreaker"] = None, fault_hook=None, registry=None):
        self.engine = engine            # Maintenance- or Sharded- engine
        self.forest = forest
        self.pending = None
        self.plan_time: Optional[float] = None   # clock() at last prepare
        self._lock = threading.Lock()
        self.metrics = get_registry()
        self.tracer = Tracer(self.metrics)
        # ------------------------------------------ maintenance fault domain
        # breaker: consecutive prepare/commit failures gate retries with
        # exponential backoff and eventually trip to serve-only mode.
        # fault_hook(site): injected by the serving layer (faultinject's
        # fault_point) — core never imports serving.
        self.breaker = breaker if breaker is not None else \
            MaintenanceBreaker()
        self._fault = fault_hook if fault_hook is not None \
            else (lambda site: None)
        # registry: a core.bank.TenantRegistry makes the fault domain
        # *per-tenant*: a failure whose cycle carried one tenant's
        # mutations feeds that tenant's breaker (template: breaker.spawn)
        # instead of the global one, and a blocked tenant's queued ops are
        # held back — only that tenant degrades to serve-only while every
        # other tenant keeps full maintenance service.
        self.registry = registry
        self.tenant_breakers: Dict[str, MaintenanceBreaker] = {}
        self._fault_tenants: set = set()     # blamed by the last failure
        self._pending_tenants: set = set()   # carried by the staged plan
        # dirty: a prepare/commit failed after the bank may have advanced
        # past the device content — the next successful prepare must stage
        # a (full) plan even if that cycle's maintain() reports no change,
        # and absorbs are skipped (bank/device layouts may disagree).
        self._dirty = False
        self.last_error: Optional[BaseException] = None
        engine.mark_staged()            # caller attaches a freshly staged
        #                                 state over this engine's bank

    def _packing_gauges(self) -> None:
        """Refresh the bank-packing gauges from ``packing_stats()`` —
        the load / overprovision / FPR surface the ROADMAP's self-tuning
        item tunes against."""
        if not self.metrics.enabled:
            return
        p = self.engine.packing_stats()
        g = self.metrics.gauge
        g("maint.overprovision",
          "live arena rows / ideal fresh-build rows").set(
              p["overprovision"])
        g("maint.arena_rows", "live arena rows").set(p["arena_rows"])
        g("maint.dead_rows", "tombstoned CSR rows").set(p["dead_rows"])
        if p["load"]:
            g("maint.load_max", "hottest tree load factor").set(
                max(p["load"]))
            g("maint.est_fpr_max",
              "worst per-tree empirical FPR estimate").set(
                  max(p["est_fpr"]))

    @property
    def deferring(self) -> bool:
        """True while a staged plan awaits commit — skip absorbs."""
        return self.pending is not None

    @property
    def dirty(self) -> bool:
        """True after a quarantined failure until the recovery commit —
        the next prepare must stage a plan even on a no-change cycle."""
        return self._dirty

    @property
    def degraded(self) -> bool:
        """True while the breaker is open — serve-only mode (answers come
        from the last committed state: stale but correct)."""
        return self.breaker.state == MaintenanceBreaker.OPEN

    def allow(self, now: float) -> bool:
        """May a maintenance attempt start at ``now``?  Gated by the
        breaker's backoff/cooldown schedule."""
        return self.breaker.allow(now)

    # ------------------------------------- per-tenant fault domains
    def tenant_breaker(self, name: str) -> "MaintenanceBreaker":
        """The (lazily spawned) breaker scoping ``name``'s maintenance
        fault domain.  Spawned from the global breaker's schedule; only
        tenants a failure has ever been attributed to get one."""
        b = self.tenant_breakers.get(name)
        if b is None:
            b = self.tenant_breakers[name] = self.breaker.spawn(name)
        return b

    @property
    def degraded_tenants(self) -> List[str]:
        """Tenants whose breaker is open — their mutations are held back
        (serve-only for them) while every other tenant keeps full
        service."""
        return sorted(n for n, b in self.tenant_breakers.items()
                      if b.state == MaintenanceBreaker.OPEN)

    def _engine_views(self):
        """``[(engine, global-tree base)]`` — one view per shard-local
        engine, with the offset that maps its delta's tree ids back to
        the registry's global numbering."""
        eng = self.engine
        if hasattr(eng, "engines"):            # ShardedMaintenanceEngine
            starts = eng.sbank.tree_starts
            return [(e, int(starts[d])) for d, e in enumerate(eng.engines)]
        return [(eng, 0)]

    def _hold_blocked(self, now: float):
        """Partition the queued deltas by tenant breaker: ops of tenants
        whose breaker disallows an attempt at ``now`` are pulled out of
        the engines' deltas (re-queued after the cycle, see
        ``_requeue``), so one tenant's quarantine never blocks the ops
        this cycle *does* carry.  Returns ``(held, involved)`` — the
        held-back ``(engine, BankDelta)`` pairs and the tenant names
        whose ops remain in flight (the blame set if this cycle fails)."""
        held: List[Tuple[object, BankDelta]] = []
        involved: set = set()
        if self.registry is None:
            return held, involved
        allowed: Dict[Optional[str], bool] = {None: True}
        for e, base in self._engine_views():
            if not e.delta:
                continue
            keep, hold = BankDelta(), BankDelta()
            for kind in ("inserts", "deletes"):
                for op in getattr(e.delta, kind):
                    name = self.registry.tenant_of(op[0] + base)
                    if name not in allowed:
                        b = self.tenant_breakers.get(name)
                        allowed[name] = b is None or b.allow(now)
                    if allowed[name]:
                        getattr(keep, kind).append(op)
                        if name is not None:
                            involved.add(name)
                    else:
                        getattr(hold, kind).append(op)
            if hold:
                e.delta = keep
                held.append((e, hold))
        return held, involved

    @staticmethod
    def _requeue(held) -> None:
        """Put held-back ops at the front of the (possibly fresh) deltas
        so a recovered tenant's mutations apply in their queued order
        relative to anything queued while it was degraded."""
        for e, hold in held:
            e.delta.inserts[:0] = hold.inserts
            e.delta.deletes[:0] = hold.deletes

    def _quarantine(self, phase: str, now: Optional[float],
                    exc: BaseException, tenants=()) -> None:
        """A prepare/commit raised: drop the failed plan, invalidate the
        diff shadow (next successful prepare restages full, from the
        always-consistent host bank — the rollback target is whatever the
        device currently serves, which the failure never touched), mark
        the lifecycle dirty, and feed the breaker.

        ``tenants`` is the blame set — the tenants whose mutations were
        in flight this cycle.  When non-empty (or when the last failure's
        blame carries over through an op-less recovery cycle), *their*
        breakers record the failure and the global breaker stays closed:
        the fault domain is the tenant, not the engine."""
        self.pending = None
        self.plan_time = None
        self._pending_tenants = set()
        self._dirty = True
        self.last_error = exc
        self.engine.invalidate_shadow()
        t = time.monotonic() if now is None else now
        blame = set(tenants) or self._fault_tenants
        if blame:
            self._fault_tenants = blame
            for name in blame:
                self.tenant_breaker(name).record_failure(t, phase)
        else:
            self.breaker.record_failure(t, phase)

    def absorb(self, state) -> int:
        """Best-effort temperature harvest: skipped (returns 0) while a
        plan is pending, the lifecycle is dirty after a failure (bank and
        device layouts may disagree — a stale absorb raises), or another
        thread holds the lifecycle lock.  Deferred bumps are never lost —
        they ride on device until the commit max-merge and the next
        successful absorb."""
        if not self._lock.acquire(blocking=False):
            return 0
        try:
            if self.pending is not None or self._dirty:
                return 0
            return self.engine.absorb(state)
        finally:
            self._lock.release()

    def prepare(self, state, now: Optional[float] = None,
                force: bool = False) -> MaintenanceReport:
        """Host maintenance pass + plan + payload staging + splice
        compilation — all overlappable with in-flight serving on the
        (still untouched) ``state``.

        A raise anywhere in the pass quarantines the cycle (failed plan
        dropped, shadow invalidated, breaker fed) and re-raises; the
        device state was never touched, so serving continues on the last
        committed content.  After a dirty failure the pass skips the
        absorb (layouts may disagree) and always stages a plan — the full
        restage from the host bank is the recovery.

        ``force=True`` stages a plan even on a no-change report and skips
        the absorb — the tenant lifecycle ops use it right after host-
        bank surgery, when the bank's arena geometry already disagrees
        with the device's.

        With a tenant registry attached, ops of tenants whose breaker
        disallows an attempt are held back for this cycle and re-queued
        after it (success or failure) — a degraded tenant is serve-only
        while every other tenant's mutations keep flowing."""
        with self._lock:
            assert self.pending is None, "commit the pending plan first"
            t = time.monotonic() if now is None else now
            held, involved = self._hold_blocked(t)
            try:
                self._fault("prepare")
                with self.tracer.span("maint.prepare") as sp:
                    with sp.stage("maintain"):
                        report = self.engine.maintain(
                            None if (self._dirty or force) else state)
                    if (report.changed or self._dirty or force) \
                            and state is not None:
                        with sp.stage("plan"):
                            self.pending = self.engine.plan_restage()
                        self.plan_time = now
                        with sp.stage("warm"):
                            warm_restage(state, self.pending)
                    sp.set(kind=getattr(self.pending, "kind", "none"),
                           changed=report.changed)
                    self._packing_gauges()
            except Exception as exc:
                self._quarantine("prepare", now, exc, involved)
                raise
            finally:
                self._requeue(held)
            self._pending_tenants = involved
            self.breaker.record_success()
            for name in involved:
                b = self.tenant_breakers.get(name)
                if b is not None:
                    b.record_success()
            return report

    def commit(self, state, blocking: bool = True,
               now: Optional[float] = None) -> Tuple[object, bool]:
        """O(changed-bytes) splice + swap; returns (new state, applied).
        With ``blocking=False`` a lock held by an in-flight prepare makes
        this a no-op (the caller retries at the next batch boundary).

        A raise quarantines the plan and re-raises; the fault fires
        before any buffer donates, so the caller's ``state`` is still the
        live, consistent pre-commit content — rollback is "keep serving
        it" and the next successful prepare restages full."""
        if not self._lock.acquire(blocking=blocking):
            return state, False
        try:
            if self.pending is None:
                return state, False
            try:
                self._fault("commit")
                # the serve-blocked window: nothing dispatches while the
                # splice applies — the histogram bench_pause gates on
                t0 = time.perf_counter()
                with self.tracer.span(
                        "maint.commit", kind=self.pending.kind,
                        changed_rows=self.pending.changed_rows) as sp:
                    with sp.stage("splice"):
                        state = commit_restage(state, self.pending,
                                               self.engine, self.forest)
                self.metrics.histogram(
                    "maint.commit_blocked_s",
                    "exclusive serve-blocked commit window").observe(
                        time.perf_counter() - t0)
            except Exception as exc:
                self._quarantine("commit", now, exc,
                                 self._pending_tenants)
                raise
            self.pending = None
            self.plan_time = None
            self._dirty = False
            self.breaker.record_success()
            for name in self._pending_tenants:
                b = self.tenant_breakers.get(name)
                if b is not None:
                    b.record_success()
            self._pending_tenants = set()
            self._fault_tenants = set()   # the recovery cycle landed
            return state, True
        finally:
            self._lock.release()
