"""Dynamic bank maintenance — incremental updates on a live FilterBank.

The paper sells the cuckoo filter over Bloom variants because it "supports
rapid membership queries and dynamic updates"; this module supplies the
*dynamic* half for the many-tree bank.  A built ``FilterBank`` is immutable
everywhere else in the codebase — any change used to mean a full vectorized
rebuild.  ``MaintenanceEngine`` mutates the live bank in place instead:

* **insert** — queued ``(tree, entity, nodes)`` rows append to the bank CSR
  arena and batch-place through ``bulk_place`` confined to each tree's
  arena segment, with the scalar kick chain as eviction fallback;
* **delete** — exact stored-hash slot removal (the host keeps the original
  32-bit hash per slot, so maintenance never deletes a fingerprint-colliding
  neighbour) with CSR row tombstoning; tombstones are reclaimed by a
  threshold-triggered **compaction** that rebuilds the CSR arena and remaps
  the slot payloads;
* **expand** — when one tree outgrows its bucket count, **only that tree's
  arena segment restages** at double ``nb_t`` (``_restage_tree``): the
  ragged layout gives every tree an independent power-of-two bucket count,
  so the segment splice shifts ``bucket_offsets`` after the hot tree and
  leaves every other segment byte-identical — no bank-wide (or, sharded,
  shard-wide) doubling, and no CSR renumbering.  Restage preserves slot
  temperatures.

Closing the paper's temperature feedback loop: the engine *harvests* device
temperature after each query batch (``absorb`` →
``FilterBank.absorb_temperature``), integrates the bump count, and a trigger
policy (``sort_threshold`` new bumps) schedules the idle-time adaptive sort
— host-side here, ``sort_buckets_arena`` on device — so hot entities
migrate to slot 0 and resolve on the first probe.

``maintain()`` is the serving engine's idle-time hook: absorb → apply
pending delta → compact if worthwhile → sort if hot enough, returning a
``MaintenanceReport`` whose ``changed`` flag tells the caller to restage
its ``CFTDeviceState`` from the mutated bank.
"""
from __future__ import annotations

import dataclasses
from typing import Dict, List, Sequence, Tuple, Union

import numpy as np

from . import hashing
from .bank import FilterBank, ShardedBank, _scalar_insert, \
    build_bank_from_rows
from .cuckoo import (DEFAULT_LOAD_THRESHOLD, DEFAULT_MAX_KICKS, NULL,
                     bulk_place)

Key = Union[str, int]              # entity name or 32-bit entity hash


def _as_hash(key: Key) -> int:
    return int(hashing.entity_hash(key)) if isinstance(key, str) \
        else int(np.uint32(key))


@dataclasses.dataclass
class BankDelta:
    """Pending mutations, recorded until the next idle window.

    Within one delta, deletes apply before inserts; inserting a key that is
    already live replaces it (old CSR row tombstoned).  Queue order between
    two operations on the *same* key in the same phase is collapsed to the
    last one queued — callers needing strict sequential semantics apply
    between ops.
    """
    inserts: List[Tuple[int, int, int, List[int]]] = \
        dataclasses.field(default_factory=list)   # (tree, hash, eid, nodes)
    deletes: List[Tuple[int, int]] = \
        dataclasses.field(default_factory=list)   # (tree, hash)

    def __len__(self) -> int:
        return len(self.inserts) + len(self.deletes)

    def __bool__(self) -> bool:
        return len(self) > 0


@dataclasses.dataclass
class MaintenanceReport:
    """What one idle-time maintenance pass did."""
    absorbed_bumps: int = 0
    inserted: int = 0
    deleted: int = 0
    replaced: int = 0
    missed_deletes: int = 0
    expansions: int = 0
    compacted: bool = False
    sorted: bool = False

    @property
    def changed(self) -> bool:
        """True when bank tables/CSR mutated — device state needs restage."""
        return bool(self.inserted or self.deleted or self.replaced
                    or self.expansions or self.compacted or self.sorted)


_TABLES = ("fingerprints", "temperature", "heads", "entity_ids",
           "stored_hash")


class MaintenanceEngine:
    """Incremental insert/delete/expand + temperature-driven sort policy
    over a live :class:`FilterBank`.

    The engine owns the bank's liveness bookkeeping: ``row_alive`` marks
    CSR rows still referenced by a filter slot, ``row_hash`` keeps each
    row's original entity hash (recovered from the built slots) so a
    restage or compaction can re-home every live row without the forest.
    Compaction renumbers CSR rows — previously returned row ids are
    invalidated, node lists (``walk_row``) are preserved exactly.
    Tree-local expansion (``expand_tree`` / automatic overflow handling)
    never renumbers rows: it splices a doubled segment into the arena and
    leaves every other tree's slots byte-identical.
    """

    def __init__(self, bank: FilterBank, seed: int = 0x5EED,
                 sort_threshold: int = 256,
                 load_threshold: float = DEFAULT_LOAD_THRESHOLD,
                 compact_min_dead: int = 32,
                 compact_dead_frac: float = 0.25,
                 max_kicks: int = DEFAULT_MAX_KICKS):
        self.bank = bank
        self.delta = BankDelta()
        self.sort_threshold = sort_threshold
        self.load_threshold = load_threshold
        self.compact_min_dead = compact_min_dead
        self.compact_dead_frac = compact_dead_frac
        self.max_kicks = max_kicks
        self._seed = seed
        self._rng = np.random.default_rng(seed)
        self.bumps_since_sort = 0
        self.stats: Dict[str, int] = {
            "inserted": 0, "deleted": 0, "replaced": 0,
            "missed_deletes": 0, "expansions": 0, "compactions": 0,
            "sorts": 0, "absorbed_bumps": 0}
        r = bank.num_rows
        self.row_alive = np.ones(r, dtype=bool)
        self.row_hash = np.zeros(r, dtype=np.uint32)
        occ = bank.fingerprints != hashing.EMPTY_FP
        self.row_hash[bank.heads[occ]] = bank.stored_hash[occ]

    # ------------------------------------------------------------ plumbing
    def _tables(self):
        """The five (A, S) arena tables, in splice order."""
        b = self.bank
        return tuple(getattr(b, n) for n in _TABLES)

    @property
    def num_dead_rows(self) -> int:
        return int((~self.row_alive).sum())

    def _find_slots(self, trees: np.ndarray, hs_q: np.ndarray
                    ) -> Tuple[np.ndarray, np.ndarray]:
        """Exact-hash slot search (``FilterBank.find_exact``): maintenance
        matches on the stored 32-bit hash, not the 12-bit fingerprint, so
        it never mutates a colliding neighbour's slot."""
        return self.bank.find_exact(trees, hs_q)

    # ------------------------------------------------------------ queueing
    def _check_tree(self, tree: int) -> int:
        # reject at queue time: an out-of-range tree discovered mid-apply
        # would leave the CSR arena mutated but the placement crashed
        if not 0 <= tree < self.bank.num_trees:
            raise ValueError(f"tree {tree} out of range "
                             f"[0, {self.bank.num_trees})")
        return tree

    def queue_insert(self, tree: int, key: Key, nodes: Sequence[int],
                     entity_id: int = NULL) -> None:
        """Record a (tree, entity) row for the next apply; ``nodes`` are
        the entity's node ids within that tree (its CSR row)."""
        self.delta.inserts.append((self._check_tree(int(tree)),
                                   _as_hash(key), int(entity_id),
                                   [int(n) for n in nodes]))

    def queue_delete(self, tree: int, key: Key) -> None:
        self.delta.deletes.append((self._check_tree(int(tree)),
                                   _as_hash(key)))

    # --------------------------------------------------------- direct ops
    def insert(self, tree: int, key: Key, nodes: Sequence[int],
               entity_id: int = NULL) -> None:
        """Queue + apply a single insert (bulk callers should queue)."""
        self.queue_insert(tree, key, nodes, entity_id)
        self.apply()

    def delete(self, tree: int, key: Key) -> bool:
        self.queue_delete(tree, key)
        before = self.stats["deleted"]
        self.apply()
        return self.stats["deleted"] > before

    # ------------------------------------------------------------- deletes
    def _clear_slots(self, rows: np.ndarray, slots: np.ndarray,
                     trees: np.ndarray) -> int:
        """Clear found slots + tombstone their CSR rows; returns count."""
        found = rows >= 0
        if not found.any():
            return 0
        fps, temps, heads, eids, hs = self._tables()
        r, s = rows[found], slots[found]
        rids = heads[r, s].astype(np.int64)
        fps[r, s] = hashing.EMPTY_FP
        temps[r, s] = 0
        heads[r, s] = NULL
        eids[r, s] = NULL
        hs[r, s] = 0
        self.row_alive[rids] = False
        b = self.bank
        b.num_items -= np.bincount(trees[found],
                                   minlength=b.num_trees).astype(np.int32)
        return int(found.sum())

    def _apply_deletes(self, trees: np.ndarray, hs_q: np.ndarray
                       ) -> Tuple[int, int]:
        rows, slots = self._find_slots(trees, hs_q)
        n = self._clear_slots(rows, slots, trees)
        return n, int(trees.shape[0]) - n

    # ------------------------------------------------------------- inserts
    def _append_rows(self, trees: np.ndarray, hs_q: np.ndarray,
                     eids: np.ndarray, nodes: List[List[int]]) -> np.ndarray:
        """Grow the CSR arena by one row per insert; returns new row ids."""
        b = self.bank
        k = trees.shape[0]
        lens = np.asarray([len(ns) for ns in nodes], dtype=np.int32)
        base = int(b.csr_offsets[-1])
        new_off = base + np.cumsum(lens, dtype=np.int32)
        b.csr_offsets = np.concatenate([b.csr_offsets, new_off])
        flat = (np.concatenate([np.asarray(ns, np.int32) for ns in nodes])
                if lens.sum() else np.zeros(0, np.int32))
        b.csr_nodes = np.concatenate([b.csr_nodes, flat])
        r0 = b.num_rows
        b.row_tree = np.concatenate([b.row_tree, trees.astype(np.int32)])
        b.row_entity = np.concatenate([b.row_entity, eids.astype(np.int32)])
        self.row_alive = np.concatenate([self.row_alive, np.ones(k, bool)])
        self.row_hash = np.concatenate([self.row_hash,
                                        hs_q.astype(np.uint32)])
        return np.arange(r0, r0 + k, dtype=np.int32)

    def _apply_inserts(self, trees: np.ndarray, hs_q: np.ndarray,
                       eids: np.ndarray, nodes: List[List[int]]
                       ) -> Tuple[int, int]:
        b = self.bank
        # replace-existing: a live (tree, hash) is deleted first so the
        # one-slot-per-key invariant (and churn equivalence) holds
        rows, slots = self._find_slots(trees, hs_q)
        replaced = self._clear_slots(rows, slots, trees)

        # per-tree pre-expansion so every receiving tree stays under the
        # load threshold — tree-local: only the overflowing trees restage
        adds = np.bincount(trees, minlength=b.num_trees)
        over = (b.num_items + adds) >= \
            self.load_threshold * b.tree_nb.astype(np.int64) * b.slots
        for t in np.flatnonzero(over):
            nb = int(b.tree_nb[t])
            target = int(b.num_items[t]) + int(adds[t])
            while target >= self.load_threshold * nb * b.slots:
                nb *= 2
            self._restage_tree(int(t), nb)
            self.stats["expansions"] += 1

        new_rows = self._append_rows(trees, hs_q, eids, nodes)
        fp = hashing.fingerprint(hs_q)
        mask = (b.tree_nb[trees] - 1).astype(np.uint32)
        i1 = hashing.bucket_i1_masked(hs_q, mask)
        i2 = hashing.alt_bucket_masked(i1, fp, mask)
        base = b.bucket_offsets[trees].astype(np.int64)
        arena_base, arena_mask = b.arena_base_mask()
        r_head, r_eid, r_hash, r_temp = bulk_place(
            *self._tables(), fp, base + i1.astype(np.int64),
            base + i2.astype(np.int64), new_rows, eids.astype(np.int32),
            hs_q, nb=0, rng=self._rng, row_base=arena_base,
            row_mask=arena_mask)
        b.num_items += np.bincount(trees,
                                   minlength=b.num_trees).astype(np.int32)
        # scalar eviction fallback; a dead kick chain restages ONLY the
        # failing tree's segment at double nb (the tree-local restage
        # re-homes every live row of that tree, including the still-
        # homeless remainder, so later remainder items of a restaged tree
        # are already placed and must be skipped)
        restaged = set()
        for j in range(r_head.size):
            rid = int(r_head[j])
            tree = int(b.row_tree[rid])
            if tree in restaged:
                continue
            lo, _ = b.segment(tree)
            if not _scalar_insert(
                    *self._tables(), lo, int(b.tree_nb[tree]),
                    b.slots, int(r_hash[j]), rid, int(r_eid[j]),
                    self._rng, self.max_kicks, temp=int(r_temp[j])):
                self._restage_tree(tree, 2 * int(b.tree_nb[tree]))
                self.stats["expansions"] += 1
                restaged.add(tree)
        return int(trees.shape[0]), replaced

    # ------------------------------------------------------------- apply
    @staticmethod
    def _dedupe_last(trees: np.ndarray, hs_q: np.ndarray) -> np.ndarray:
        """Indices keeping only the last occurrence of each (tree, hash)."""
        key = trees.astype(np.uint64) << np.uint64(32) | \
            hs_q.astype(np.uint64)
        _, idx = np.unique(key[::-1], return_index=True)
        return np.sort(key.shape[0] - 1 - idx)

    def apply(self) -> Dict[str, int]:
        """Apply the pending delta: deletes, then inserts (bulk_place with
        scalar fallback).  Returns per-call stats."""
        d, self.delta = self.delta, BankDelta()
        out = {"inserted": 0, "deleted": 0, "replaced": 0,
               "missed_deletes": 0}
        if d.deletes:
            trees = np.asarray([t for t, _ in d.deletes], np.int64)
            hs_q = np.asarray([h for _, h in d.deletes], np.uint32)
            keep = self._dedupe_last(trees, hs_q)
            n, miss = self._apply_deletes(trees[keep], hs_q[keep])
            out["deleted"] = n
            out["missed_deletes"] = miss
        if d.inserts:
            trees = np.asarray([t for t, _, _, _ in d.inserts], np.int64)
            hs_q = np.asarray([h for _, h, _, _ in d.inserts], np.uint32)
            eids = np.asarray([e for _, _, e, _ in d.inserts], np.int64)
            keep = self._dedupe_last(trees, hs_q)
            nodes = [d.inserts[int(i)][3] for i in keep]
            n, rep = self._apply_inserts(trees[keep], hs_q[keep],
                                         eids[keep], nodes)
            out["inserted"] = n
            out["replaced"] = rep
        for k, v in out.items():
            self.stats[k] += v
        return out

    # --------------------------------------------------- expand / compact
    def _restage_tree(self, tree: int, new_nb: int) -> None:
        """Tree-local restage: re-place only ``tree``'s live rows into a
        fresh ``(new_nb, S)`` segment and splice it into the arena.

        Everything outside the segment is untouched byte-for-byte — only
        ``bucket_offsets`` after the tree shift by the size delta.  CSR
        rows are *not* renumbered (no compaction), so previously returned
        row ids and every other tree's head payloads stay valid.  Slot
        temperatures are preserved; rows that are alive but currently
        homeless (a mid-insert remainder) are placed too.
        """
        b = self.bank
        lo, hi = b.segment(tree)
        s = b.slots
        temp_r = np.zeros(max(b.num_rows, 1), np.int32)
        occ = b.fingerprints[lo:hi] != hashing.EMPTY_FP
        temp_r[b.heads[lo:hi][occ]] = b.temperature[lo:hi][occ]
        rows = np.flatnonzero(self.row_alive
                              & (b.row_tree == tree)).astype(np.int64)
        hs_q = self.row_hash[rows]
        eids = b.row_entity[rows].astype(np.int32)
        nb = int(new_nb)
        while True:
            self._seed += 1
            rng = np.random.default_rng(self._seed)
            seg = (np.full((nb, s), hashing.EMPTY_FP, np.uint32),
                   np.zeros((nb, s), np.int32),
                   np.full((nb, s), NULL, np.int32),
                   np.full((nb, s), NULL, np.int32),
                   np.zeros((nb, s), np.uint32))
            fp = hashing.fingerprint(hs_q)
            i1 = hashing.bucket_i1(hs_q, nb)
            i2 = hashing.alt_bucket(i1, fp, nb)
            r_head, r_eid, r_hash, r_temp = bulk_place(
                *seg, fp, i1.astype(np.int64), i2.astype(np.int64),
                rows.astype(np.int32), eids, hs_q, nb=nb, rng=rng,
                new_temps=temp_r[rows])
            ok = True
            for j in range(r_head.size):
                if not _scalar_insert(*seg, 0, nb, s, int(r_hash[j]),
                                      int(r_head[j]), int(r_eid[j]), rng,
                                      self.max_kicks, temp=int(r_temp[j])):
                    ok = False
                    break
            if ok and rows.size < self.load_threshold * nb * s:
                break
            nb *= 2
        for name, new_seg in zip(_TABLES, seg):
            old = getattr(b, name)
            setattr(b, name, np.concatenate([old[:lo], new_seg, old[hi:]]))
        delta = nb - int(b.tree_nb[tree])
        b.tree_nb[tree] = nb
        b.bucket_offsets[tree + 1:] += delta
        b.num_items[tree] = rows.size

    def _rebuild(self, tree_nb: np.ndarray) -> None:
        """Restage the whole bank at the given per-tree bucket counts:
        compact the CSR arena to live rows, re-place every live row
        (temperatures preserved), and adopt the new tables into the
        existing bank object so external references stay valid."""
        b = self.bank
        occ = b.fingerprints != hashing.EMPTY_FP
        temp_r = np.zeros(max(b.num_rows, 1), np.int32)
        temp_r[b.heads[occ]] = b.temperature[occ]

        live = np.flatnonzero(self.row_alive)
        starts = b.csr_offsets[live].astype(np.int64)
        lens = (b.csr_offsets[live + 1].astype(np.int64) - starts)
        new_off = np.zeros(live.size + 1, dtype=np.int32)
        np.cumsum(lens, out=new_off[1:])
        total = int(lens.sum())
        pos = np.arange(total, dtype=np.int64)
        idx = pos + np.repeat(starts - new_off[:-1], lens)
        new_nodes = (b.csr_nodes[idx] if total else np.zeros(0, np.int32))

        self._seed += 1
        fresh = build_bank_from_rows(
            b.num_trees, b.row_tree[live], b.row_entity[live],
            self.row_hash[live], new_off, new_nodes,
            num_buckets=np.asarray(tree_nb, np.int64), slots=b.slots,
            seed=self._seed, max_kicks=self.max_kicks,
            row_temp=temp_r[live])
        for f in dataclasses.fields(FilterBank):
            setattr(b, f.name, getattr(fresh, f.name))
        self.row_hash = self.row_hash[live].copy()
        self.row_alive = np.ones(live.size, dtype=bool)

    def expand(self) -> None:
        """Bank-wide restage with every tree at double nb (temperatures
        preserved).  Rarely what you want with the ragged arena — prefer
        :meth:`expand_tree`, which grows only the hot tree."""
        self._rebuild(self.bank.tree_nb.astype(np.int64) * 2)
        self.stats["expansions"] += 1

    def expand_tree(self, tree: int, force: bool = False) -> bool:
        """Single-tree expansion: restage only ``tree``'s arena segment at
        double ``nb_t``.  Every other segment stays byte-identical and CSR
        rows keep their ids — O(hot tree), not O(bank).  No-op unless that
        tree is actually past the load threshold, or ``force``.

        Direct calls change the arena geometry, so any device state staged
        from this bank must be restaged before its temperature is absorbed
        (a stale absorb raises loudly).  Overflow expansion inside
        ``maintain()`` needs no care: it runs after the absorb, and the
        caller restages on ``report.changed``."""
        b = self.bank
        load = float(b.num_items[tree]) / (int(b.tree_nb[tree]) * b.slots)
        if not force and load < self.load_threshold:
            return False
        self._restage_tree(int(tree), 2 * int(b.tree_nb[tree]))
        self.stats["expansions"] += 1
        return True

    def compact(self) -> bool:
        """Reclaim tombstoned CSR rows (per-tree nb preserved); returns
        True if ran."""
        if self.num_dead_rows == 0:
            return False
        self._rebuild(self.bank.tree_nb.astype(np.int64).copy())
        self.stats["compactions"] += 1
        return True

    def maybe_compact(self) -> bool:
        dead = self.num_dead_rows
        total = max(1, self.bank.num_rows)
        if dead >= self.compact_min_dead and \
                dead / total >= self.compact_dead_frac:
            return self.compact()
        return False

    # --------------------------------------------- temperature feedback
    def absorb(self, device_state) -> int:
        """Harvest device temperature into the host bank; accumulate the
        bump count the sort trigger integrates."""
        bumps = self.bank.absorb_temperature(device_state)
        self.bumps_since_sort += bumps
        self.stats["absorbed_bumps"] += bumps
        return bumps

    def sort(self) -> None:
        """Host-side bank-wide idle sort (hot fingerprints to slot 0)."""
        self.bank.sort_buckets()
        self.bumps_since_sort = 0
        self.stats["sorts"] += 1

    def maybe_sort(self) -> bool:
        if self.bumps_since_sort >= self.sort_threshold:
            self.sort()
            return True
        return False

    # ------------------------------------------------------ idle-time hook
    def maintain(self, device_state=None) -> MaintenanceReport:
        """One idle-window pass: absorb device temperature (must run before
        any slot moves so layouts agree), apply the pending delta, compact
        if enough rows died, sort if enough heat accumulated.  The caller
        restages its device state iff ``report.changed``."""
        rep = MaintenanceReport()
        if device_state is not None:
            rep.absorbed_bumps = self.absorb(device_state)
        exp0 = self.stats["expansions"]
        if self.delta:
            out = self.apply()
            rep.inserted = out["inserted"]
            rep.deleted = out["deleted"]
            rep.replaced = out["replaced"]
            rep.missed_deletes = out["missed_deletes"]
        rep.compacted = self.maybe_compact()
        rep.sorted = self.maybe_sort()
        rep.expansions = self.stats["expansions"] - exp0
        return rep


class ShardedMaintenanceEngine:
    """Shard-local maintenance over a :class:`ShardedBank`.

    One :class:`MaintenanceEngine` per shard, each owning only its shard's
    sub-bank: global-tree operations route to the owning shard's engine
    (``tree_starts`` range search), so an insert, delete, compaction or
    *expansion* mutates exactly one shard's tables.  With the ragged arena
    an expansion is narrower still: only the hot tree's segment within the
    owning shard restages — every other tree's segment (same shard or not)
    stays byte-identical, and a restage after maintenance ships only
    changed blocks' worth of new content.

    Temperature harvesting slices the packed ``(D*Apad, S)`` device arena
    into per-shard owner blocks first (``ShardedBank.temperature_blocks``),
    so each slot's bumps are counted once against the owning shard's own
    baseline — the padding rows of the packed layout never enter the delta.
    """

    def __init__(self, sbank: ShardedBank, seed: int = 0x5EED, **policy):
        self.sbank = sbank
        # distinct per-shard seeds: shard-local kick chains must not be
        # correlated replicas of each other
        self.engines = [MaintenanceEngine(b, seed=seed + 101 * d, **policy)
                        for d, b in enumerate(sbank.banks)]

    # ------------------------------------------------------------ routing
    def _owner(self, tree: int) -> Tuple[int, int]:
        return self.sbank.owner(int(tree))

    def queue_insert(self, tree: int, key: Key, nodes: Sequence[int],
                     entity_id: int = NULL) -> None:
        d, lt = self._owner(tree)
        self.engines[d].queue_insert(lt, key, nodes, entity_id)

    def queue_delete(self, tree: int, key: Key) -> None:
        d, lt = self._owner(tree)
        self.engines[d].queue_delete(lt, key)

    def insert(self, tree: int, key: Key, nodes: Sequence[int],
               entity_id: int = NULL) -> None:
        d, lt = self._owner(tree)
        self.engines[d].insert(lt, key, nodes, entity_id)

    def delete(self, tree: int, key: Key) -> bool:
        d, lt = self._owner(tree)
        return self.engines[d].delete(lt, key)

    def apply(self) -> Dict[str, int]:
        out = {"inserted": 0, "deleted": 0, "replaced": 0,
               "missed_deletes": 0}
        for e in self.engines:
            if e.delta:
                for k, v in e.apply().items():
                    out[k] += v
        return out

    # --------------------------------------------------- expand / compact
    def expand_tree(self, tree: int, force: bool = False) -> bool:
        """Tree-local expansion: restages only the hot tree's arena
        segment within its owning shard — the other trees' segments (and
        every other shard) are untouched."""
        d, lt = self._owner(tree)
        return self.engines[d].expand_tree(lt, force=force)

    def maybe_compact(self) -> bool:
        return any([e.maybe_compact() for e in self.engines])

    # --------------------------------------------- temperature feedback
    def absorb(self, device_state) -> int:
        blocks = self.sbank.temperature_blocks(device_state)
        return sum(e.absorb(blk)
                   for e, blk in zip(self.engines, blocks))

    def maybe_sort(self) -> bool:
        return any([e.maybe_sort() for e in self.engines])

    # ------------------------------------------------------ idle-time hook
    def maintain(self, device_state=None) -> MaintenanceReport:
        """One idle-window pass over every shard (absorb -> delta ->
        compact -> sort, shard by shard).  The packed temperature is sliced
        against the *pre-mutation* geometry up front, so an expansion on an
        earlier shard cannot shift a later shard's harvest window."""
        blocks = (self.sbank.temperature_blocks(device_state)
                  if device_state is not None
                  else [None] * self.sbank.num_shards)
        rep = MaintenanceReport()
        for e, blk in zip(self.engines, blocks):
            r = e.maintain(blk)
            rep.absorbed_bumps += r.absorbed_bumps
            rep.inserted += r.inserted
            rep.deleted += r.deleted
            rep.replaced += r.replaced
            rep.missed_deletes += r.missed_deletes
            rep.expansions += r.expansions
            rep.compacted = rep.compacted or r.compacted
            rep.sorted = rep.sorted or r.sorted
        return rep

    # ------------------------------------------------------------- stats
    @property
    def stats(self) -> Dict[str, int]:
        out: Dict[str, int] = {}
        for e in self.engines:
            for k, v in e.stats.items():
                out[k] = out.get(k, 0) + v
        return out

    @property
    def bumps_since_sort(self) -> int:
        return sum(e.bumps_since_sort for e in self.engines)

    @property
    def num_dead_rows(self) -> int:
        return sum(e.num_dead_rows for e in self.engines)
